//! Sharded-execution integration tests (DESIGN.md §3.8): multi-chip
//! plans must be *bit-exact* with the unsharded plan on both execution
//! paths — the cycle-level engine and the tile-parallel batched path —
//! for every model, pipeline depth, and shard count, while billing the
//! halo exchange into the timing result.

use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::{Coordinator, InferenceRequest};
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];

fn run_cfg(model: &str, layers: u32, shards: u32) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        layers,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        serving: Default::default(),
        kernels: Default::default(),
        shards,
        overlap: false,
    }
}

/// The acceptance matrix: all five models × depths {1, 2, 3} × K ∈
/// {2, 3}, engine AND batched path, all bit-exact with the unsharded
/// plan (and with each other).
#[test]
fn sharded_outputs_are_bit_exact_across_models_depths_and_k() {
    let arch = ArchConfig::default();
    for model in MODELS {
        for depth in [1u32, 2, 3] {
            let base = ExecPlan::compile(&run_cfg(model, depth, 1)).unwrap();
            assert!(base.sharding.is_none());
            let x = base.make_input(17);
            let want = base
                .simulate(&arch, true, Some(&x), 0)
                .unwrap()
                .output
                .unwrap();
            for k in [2u32, 3] {
                let tag = format!("{model} depth={depth} k={k}");
                let plan = ExecPlan::compile(&run_cfg(model, depth, k)).unwrap();
                let sh = plan.sharding.as_ref().expect("K>=2 plan must be sharded");
                assert_eq!(sh.num_shards(), k as usize, "{tag}");
                let res = plan.simulate(&arch, true, Some(&x), 0).unwrap();
                assert_eq!(res.output.as_ref(), Some(&want), "{tag}: engine path diverged");
                // both lanes of a batched pass agree too
                let mut scratch = BatchScratch::new();
                let outs = plan.execute_batch_with(&[&x, &x], 3, &mut scratch).unwrap();
                assert_eq!(outs[0], want, "{tag}: batched path diverged");
                assert_eq!(outs[1], want, "{tag}: batched lanes diverged");
            }
        }
    }
}

/// Halo accounting: K ≥ 2 multi-layer runs pay one exchange per layer
/// boundary, the cost lands in the layer breakdown, and the per-layer
/// cycles still sum to the total.
#[test]
fn halo_exchange_is_billed_into_timing() {
    let arch = ArchConfig::default();
    let plan = ExecPlan::compile(&run_cfg("gcn", 3, 2)).unwrap();
    let res = plan.simulate(&arch, false, None, 0).unwrap();
    assert_eq!(res.halo.exchanges, 2, "depth-3 run has two layer boundaries");
    assert!(res.halo.vertices > 0, "CR cut must produce halo vertices");
    assert!(res.halo.bytes > 0 && res.halo.cycles > 0);
    assert_eq!(res.cycles, res.layers.iter().map(|l| l.cycles).sum::<u64>());
    assert_eq!(
        res.dram_read_bytes,
        res.layers.iter().map(|l| l.dram_read_bytes).sum::<u64>()
    );
    // the exchange bytes are part of the DRAM/HBM story, split evenly
    // between producer writes and consumer reads
    let unsharded = ExecPlan::compile(&run_cfg("gcn", 3, 1))
        .unwrap()
        .simulate(&arch, false, None, 0)
        .unwrap();
    assert_eq!(unsharded.halo.exchanges, 0);
    assert!(
        res.dram_read_bytes >= unsharded.dram_read_bytes,
        "sharding must not lose DRAM traffic"
    );
    // final-layer boundary has no exchange: last layer carries no halo cost
    let depth1 = ExecPlan::compile(&run_cfg("gcn", 1, 2)).unwrap();
    let r1 = depth1.simulate(&arch, false, None, 0).unwrap();
    assert_eq!(r1.halo.exchanges, 0, "depth-1 has no layer boundary");
}

/// Shard timing is max-over-chips per layer, not a sum: a K=2 layer
/// must be no slower than the unsharded layer plus the exchange.
#[test]
fn sharded_layers_run_concurrently() {
    let arch = ArchConfig::default();
    let one = ExecPlan::compile(&run_cfg("gcn", 2, 1))
        .unwrap()
        .simulate(&arch, false, None, 0)
        .unwrap();
    let two = ExecPlan::compile(&run_cfg("gcn", 2, 2))
        .unwrap()
        .simulate(&arch, false, None, 0)
        .unwrap();
    assert!(
        two.cycles < one.cycles + two.halo.cycles + one.cycles / 4,
        "K=2 ({}) should not approach 2x the unsharded critical path ({})",
        two.cycles,
        one.cycles
    );
    // event counts stay additive across chips: halo vertices are
    // re-loaded on consumer chips, so the sharded total can only grow
    assert!(two.instructions >= one.instructions, "sharding must not lose work");
}

/// End-to-end through the serving runtime: a sharded RunConfig flows
/// coordinator → plan cache → batched worker, reports halo bytes, and
/// checksums match the unsharded request exactly.
#[test]
fn sharded_requests_serve_bit_exact_through_the_coordinator() {
    let mut c = Coordinator::new(ArchConfig::default(), 2);
    c.submit(InferenceRequest { id: 0, run: run_cfg("gat", 2, 1), input_seed: 7 });
    c.submit(InferenceRequest { id: 1, run: run_cfg("gat", 2, 2), input_seed: 7 });
    let mut resp = c.drain();
    resp.sort_by_key(|r| r.id);
    assert!(resp.iter().all(|r| r.error.is_none()), "{:?}", resp);
    assert_eq!(resp[0].halo_bytes, 0, "unsharded run reports no halo traffic");
    assert!(resp[1].halo_bytes > 0, "sharded run must report halo traffic");
    assert_eq!(
        resp[0].output_checksum, resp[1].output_checksum,
        "sharded serving output must match unsharded"
    );
    // sharded and unsharded plans never alias in the cache
    assert_eq!(c.cache_stats().entries, 2);
}
