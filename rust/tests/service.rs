//! Robustness tests for the always-on serving runtime
//! (`coordinator::service::ZipperService`): dual-trigger batching,
//! latency accounting, deadline shedding, graceful shutdown, and
//! exactly-once response delivery under injected worker panics.
//!
//! CI reruns this file with `--test-threads=1` to catch timer/ordering
//! races that parallel test scheduling can mask.

use std::sync::Arc;
use std::time::{Duration, Instant};
use zipper::config::{ArchConfig, OverflowPolicy, RunConfig, ServingConfig};
use zipper::coordinator::service::INJECT_PANIC_SEED;
use zipper::coordinator::{InferenceRequest, RejectReason, Ticket, ZipperService};
use zipper::plan::PlanCache;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

fn small_run(model: &str, functional: bool) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        layers: 1,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional,
        seed: 3,
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

fn req(id: u64, run: RunConfig) -> InferenceRequest {
    InferenceRequest { id, run, input_seed: id }
}

fn service(workers: usize, serving: ServingConfig) -> ZipperService {
    ZipperService::new(ArchConfig::default(), workers, serving, Arc::new(PlanCache::new()))
        .expect("valid serving config")
}

#[test]
fn timer_trigger_flushes_partial_batches_without_drain() {
    // 3 same-plan requests into an 8-wide accumulator: the fill trigger
    // can never fire, so only the max_wait_us dispatcher timer can
    // deliver these responses — no drain/shutdown involved.
    let serving = ServingConfig { max_batch: 8, max_wait_us: 5_000, ..Default::default() };
    let svc = service(1, serving);
    let tickets: Vec<Ticket> =
        (0..3).map(|i| svc.submit(req(i, small_run("gcn", true)))).collect();
    for t in tickets {
        let r = t.wait(); // resolves via the timer flush
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.batch_size, 3, "timer must flush the whole partial group");
    }
    let report = svc.shutdown(Duration::from_secs(30));
    assert!(report.graceful);
    let m = svc.metrics();
    assert_eq!((m.submitted, m.completed), (3, 3));
    assert_eq!(m.batch_size_hist[3], 1);
}

#[test]
fn fill_trigger_dispatches_full_batches_before_the_timer() {
    // 8 submits into an 8-wide group with a far-future timer: the fill
    // trigger must dispatch immediately; a 60 s max_wait would time the
    // test out if the timer were the only path.
    let serving = ServingConfig { max_batch: 8, max_wait_us: 60_000_000, ..Default::default() };
    let svc = service(1, serving);
    let tickets: Vec<Ticket> =
        (0..8).map(|i| svc.submit(req(i, small_run("gcn", true)))).collect();
    for t in tickets {
        let r = t.wait();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.batch_size, 8);
    }
    svc.shutdown(Duration::from_secs(30));
}

#[test]
fn queue_seconds_regression_delayed_dispatch_shows_queue_time() {
    // Regression for the latency-accounting fix: wall_seconds used to
    // start at worker batch-receipt, silently excluding queue wait. Hold
    // a request in the accumulator for ~40 ms via the timer and check
    // the wait is visible in queue_seconds and contained in
    // wall_seconds.
    let serving = ServingConfig { max_batch: 4, max_wait_us: 40_000, ..Default::default() };
    let svc = service(1, serving);
    let t = svc.submit(req(0, small_run("gcn", false)));
    let r = t.wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert!(
        r.queue_seconds >= 0.030,
        "a ~40 ms timer hold must show up as queue time, got {}",
        r.queue_seconds
    );
    assert!(
        r.wall_seconds >= r.queue_seconds,
        "wall ({}) must span submit→response and contain queue wait ({})",
        r.wall_seconds,
        r.queue_seconds
    );
    svc.shutdown(Duration::from_secs(30));
}

#[test]
fn deadline_expired_in_queue_is_shed_at_dispatch() {
    // The request is admitted with 20 ms of budget, parks in an 8-wide
    // accumulator behind a 60 s timer, and is only flushed by shutdown
    // after the budget is gone — dispatch must shed it, not execute it.
    let serving = ServingConfig { max_batch: 8, max_wait_us: 60_000_000, ..Default::default() };
    let svc = service(1, serving);
    let deadline = Instant::now() + Duration::from_millis(20);
    let t = svc.submit_with_deadline(req(0, small_run("gcn", false)), Some(deadline));
    std::thread::sleep(Duration::from_millis(40));
    let report = svc.shutdown(Duration::from_secs(30));
    assert!(report.graceful);
    let r = t.wait();
    assert_eq!(r.reject, Some(RejectReason::DeadlineExceeded));
    assert!(r.queue_seconds >= 0.020, "the whole lifetime was queue time");
    let m = svc.metrics();
    assert_eq!(m.shed_deadline, 1, "shed at dispatch, not rejected at admission");
    assert_eq!(m.rejected_deadline, 0);
    assert_eq!(m.completed, 0);
}

#[test]
fn graceful_shutdown_answers_everything_within_grace() {
    let serving = ServingConfig { max_batch: 4, ..Default::default() };
    let svc = service(2, serving);
    // 10 requests: two full batches dispatch eagerly, 2 leftovers are
    // flushed by shutdown itself
    let tickets: Vec<Ticket> =
        (0..10).map(|i| svc.submit(req(i, small_run("gat", true)))).collect();
    let report = svc.shutdown(Duration::from_secs(60));
    assert!(report.graceful, "drain must finish within a 60 s grace");
    assert_eq!(report.shed, 0);
    for t in tickets {
        let r = t.wait();
        assert!(r.error.is_none() && r.reject.is_none(), "{:?}", r.error);
    }
    let m = svc.metrics();
    assert_eq!((m.submitted, m.completed, m.failed), (10, 10, 0));
    assert_eq!(m.rejected_total(), 0);
    assert!(m.latency_count == 10 && m.latency_p99_us >= m.latency_p50_us);
}

#[test]
fn zero_grace_shutdown_never_loses_a_response() {
    // With grace 0 the queued backlog may be served (a worker won the
    // race to pick it up) or shed with ShuttingDown — but every ticket
    // must resolve exactly once and the accounting must balance.
    let serving = ServingConfig { max_batch: 8, ..Default::default() };
    let svc = service(1, serving);
    let tickets: Vec<Ticket> =
        (0..5).map(|i| svc.submit(req(i, small_run("gcn", false)))).collect();
    let report = svc.shutdown(Duration::ZERO);
    let mut served = 0u64;
    let mut shed = 0u64;
    for t in tickets {
        let r = t.wait();
        match r.reject {
            None => {
                assert!(r.error.is_none(), "{:?}", r.error);
                served += 1;
            }
            Some(reason) => {
                assert_eq!(reason, RejectReason::ShuttingDown);
                shed += 1;
            }
        }
    }
    assert_eq!(served + shed, 5, "exactly one response per request");
    assert_eq!(report.shed, shed);
    let m = svc.metrics();
    assert_eq!(m.completed + m.rejected_shutdown, 5);
    assert_eq!((m.queue_depth, m.in_flight), (0, 0));
}

#[test]
fn blocking_overflow_applies_backpressure_without_deadlock() {
    // queue_cap 1 + Block: each submit may have to wait for the worker
    // to take the previous request; the run must make progress and
    // serve everything (nothing rejected, nothing stuck).
    let serving = ServingConfig {
        queue_cap: 1,
        overflow: OverflowPolicy::Block,
        ..Default::default()
    };
    let svc = service(1, serving);
    let tickets: Vec<Ticket> =
        (0..6).map(|i| svc.submit(req(i, small_run("gcn", false)))).collect();
    for t in tickets {
        let r = t.wait();
        assert!(r.error.is_none() && r.reject.is_none(), "{:?}", r.error);
    }
    svc.shutdown(Duration::from_secs(30));
    let m = svc.metrics();
    assert_eq!((m.submitted, m.completed), (6, 6));
    assert_eq!(m.rejected_total(), 0);
}

#[test]
fn injected_panic_exactly_one_response_across_worker_and_batch_matrix() {
    // The satellite robustness matrix: across workers {1,4} ×
    // max_batch {1,8}, poison a middle tranche of requests with the
    // panic-injection seed. Every request — queued before, poisoned,
    // and submitted after the panic — must get exactly one response:
    // healthy ones succeed, poisoned ones fail with the structured
    // worker-panicked error, nothing hangs, nothing double-counts.
    for workers in [1usize, 4] {
        for max_batch in [1u32, 8] {
            let serving = ServingConfig { max_batch, ..Default::default() };
            let svc = service(workers, serving);
            let mut tickets: Vec<(bool, Ticket)> = Vec::new();
            // phase A: healthy requests, possibly still queued at panic
            for i in 0..6 {
                tickets.push((false, svc.submit(req(i, small_run("gcn", true)))));
            }
            // phase B: poisoned requests — the injection seed joins the
            // plan key, so they batch together, never with healthy ones
            for i in 6..10 {
                let mut run = small_run("gcn", true);
                run.seed = INJECT_PANIC_SEED;
                tickets.push((true, svc.submit(req(i, run))));
            }
            // phase C: the worker must survive the panic and keep serving
            for i in 10..16 {
                tickets.push((false, svc.submit(req(i, small_run("gcn", true)))));
            }
            let report = svc.shutdown(Duration::from_secs(60));
            assert!(report.graceful, "workers={workers} max_batch={max_batch}");
            let mut responses = 0u64;
            for (poisoned, t) in tickets {
                let r = t.wait();
                responses += 1;
                assert!(r.reject.is_none(), "panics are failures, not sheds");
                if poisoned {
                    let err = r.error.as_deref().unwrap_or_else(|| {
                        panic!("workers={workers} max_batch={max_batch} id={}", r.id)
                    });
                    assert!(
                        err.contains("worker panicked") && err.contains("injected worker panic"),
                        "workers={workers} max_batch={max_batch}: {err}"
                    );
                } else {
                    assert!(
                        r.error.is_none(),
                        "workers={workers} max_batch={max_batch} id={}: {:?}",
                        r.id,
                        r.error
                    );
                    assert!(r.output_checksum.is_some());
                }
            }
            assert_eq!(responses, 16, "exactly one response per submitted request");
            let m = svc.metrics();
            assert_eq!(m.submitted, 16);
            assert_eq!((m.completed, m.failed), (12, 4));
            assert_eq!(m.rejected_total(), 0);
            assert_eq!(
                m.completed + m.failed + m.rejected_total(),
                m.submitted,
                "accounting identity must balance after a panic"
            );
            assert_eq!((m.queue_depth, m.in_flight), (0, 0));
        }
    }
}

#[test]
fn metrics_identity_holds_at_quiescent_snapshots() {
    let serving = ServingConfig { max_batch: 4, max_wait_us: 500, ..Default::default() };
    let svc = service(2, serving);
    let tickets: Vec<Ticket> = (0..9)
        .map(|i| {
            let model = if i % 2 == 0 { "gcn" } else { "sage" };
            svc.submit(req(i, small_run(model, false)))
        })
        .collect();
    for t in tickets {
        assert!(t.wait().error.is_none());
    }
    svc.shutdown(Duration::from_secs(30));
    let m = svc.metrics();
    assert_eq!(m.completed + m.failed + m.rejected_total(), m.submitted);
    assert_eq!(m.batch_size_hist.iter().sum::<u64>(), m.batches);
    assert_eq!(m.latency_count, m.completed);
    assert!(m.peak_queue_depth >= 1);
    assert!(m.latency_p50_us <= m.latency_p95_us && m.latency_p95_us <= m.latency_p99_us);
    assert!(m.plan_cache.hits + m.plan_cache.misses >= m.batches);
}
