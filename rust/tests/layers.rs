//! Multi-layer pipeline tests: depth-1 bit-exactness with the
//! pre-pipeline single-layer path, an in-repo layer-chaining oracle
//! (hand-chained single-layer plans with host-side ReLU must be
//! bit-exact with the stacked `ExecPlan`), engine ↔ batched-path
//! equivalence at depth, and the shared-tiling / cache-key guarantees.

use zipper::compiler::{compile, OptLevel};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::{Coordinator, InferenceRequest};
use zipper::graph::datasets;
use zipper::models::{ModelKind, ModelSpec, WeightStore, NUM_RELATIONS};
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::sim::{ExecScratch, SimOptions, Simulator, Workload};
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];

fn run_cfg(model: &str, layers: u32, hidden: Vec<u32>) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        layers,
        hidden,
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

/// Depth-1 pipelines must be bit-exact with the pre-pipeline path:
/// one program compiled from `ModelKind::build()`, one `WeightStore`
/// synthesized at the run seed, driven through the engine directly.
#[test]
fn depth1_pipeline_bit_exact_with_direct_single_layer_run() {
    let arch = ArchConfig::default();
    for m in MODELS {
        let run = run_cfg(m, 1, vec![]);
        let plan = ExecPlan::compile(&run).unwrap();
        assert_eq!(plan.depth(), 1, "{m}");
        let x = plan.make_input(7);
        let pipe = plan.simulate(&arch, true, Some(&x), 0).unwrap();

        let kind = ModelKind::parse(m).unwrap();
        let prog = compile(&kind.build(), OptLevel::E2v).unwrap();
        let ws = WeightStore::synthesize(&kind.build(), 16, 16, run.seed);
        let wl = Workload {
            program: &prog,
            tiling: &plan.tiling,
            weights: &ws,
            feat_in: 16,
            feat_out: 16,
            x: Some(&x),
            kernels: Default::default(),
        };
        let direct = Simulator::new(&arch, &wl, SimOptions { functional: true, ..Default::default() })
            .run()
            .unwrap();
        assert_eq!(pipe.cycles, direct.cycles, "{m}: depth-1 timing must be unchanged");
        assert_eq!(pipe.instructions, direct.instructions, "{m}");
        assert_eq!(pipe.dram_read_bytes, direct.dram_read_bytes, "{m}");
        assert_eq!(pipe.peak_uem_bytes, direct.peak_uem_bytes, "{m}");
        assert_eq!(
            pipe.output.unwrap(),
            direct.output.unwrap(),
            "{m}: depth-1 output must be bit-exact with the single-layer path"
        );
        assert_eq!(pipe.layers.len(), 1, "{m}: depth-1 still reports one layer");
    }
}

/// The in-repo layer-chaining oracle: a depth-K plan must be bit-exact
/// with K hand-chained single-layer plans — same shared graph, layer
/// weights at `ModelSpec::layer_seed`, hidden activations applied
/// host-side with the exact kernel expression (`v.max(0.0)`).
#[test]
fn multi_layer_pipeline_matches_hand_chained_layers() {
    let arch = ArchConfig::default();
    for m in MODELS {
        for depth in [2u32, 3] {
            let base = run_cfg(m, depth, vec![]);
            let plan = ExecPlan::compile(&base).unwrap();
            assert_eq!(plan.depth(), depth as usize, "{m}");
            let x = plan.make_input(11);
            let res = plan.simulate(&arch, true, Some(&x), 0).unwrap();
            let got = res.output.unwrap();
            assert_eq!(res.layers.len(), depth as usize, "{m} depth {depth}");

            // hand chain: single-layer plans over the SAME graph
            let kind = ModelKind::parse(m).unwrap();
            let etypes = if kind.uses_etypes() { NUM_RELATIONS } else { 0 };
            let graph = datasets::by_id(&base.dataset)
                .unwrap()
                .instantiate_typed(base.scale, etypes, base.seed);
            let mut cur = x.clone();
            for l in 0..depth as usize {
                let mut run_l = base.clone();
                run_l.layers = 1;
                run_l.hidden = Vec::new();
                run_l.seed = ModelSpec::layer_seed(base.seed, l);
                let lp = ExecPlan::from_graph(kind, graph.clone(), &run_l).unwrap();
                let mut out = lp.simulate(&arch, true, Some(&cur), 0).unwrap().output.unwrap();
                if l + 1 < depth as usize {
                    // hidden-layer ReLU, exactly the VU kernel's expression
                    for v in &mut out {
                        *v = v.max(0.0);
                    }
                }
                cur = out;
            }
            assert_eq!(got, cur, "{m} depth {depth}: pipeline vs hand-chained layers");
        }
    }
}

/// Engine and batched `run_batch` pipelines stay bit-exact at depth,
/// for every thread count and batch grouping.
#[test]
fn multi_layer_engine_and_batched_path_bit_exact() {
    let arch = ArchConfig::default();
    for m in ["gcn", "gat", "sage"] {
        for depth in [2u32, 3] {
            let plan = ExecPlan::compile(&run_cfg(m, depth, vec![])).unwrap();
            let inputs: Vec<Vec<f32>> = (0..6).map(|s| plan.make_input(s)).collect();
            let engine: Vec<Vec<f32>> = inputs
                .iter()
                .map(|x| plan.simulate(&arch, true, Some(x), 0).unwrap().output.unwrap())
                .collect();
            for threads in [1usize, 2, 4] {
                for batch in [1usize, 3, 8] {
                    let mut scratch = BatchScratch::new();
                    let mut got: Vec<Vec<f32>> = Vec::new();
                    for chunk in inputs.chunks(batch) {
                        let lanes: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
                        got.extend(
                            plan.execute_batch_with(&lanes, threads, &mut scratch).unwrap(),
                        );
                    }
                    assert_eq!(got.len(), engine.len());
                    for (i, (g, e)) in got.iter().zip(&engine).enumerate() {
                        assert_eq!(
                            g, e,
                            "{m} depth={depth} threads={threads} batch={batch} lane={i}"
                        );
                    }
                }
            }
        }
    }
}

/// Hidden activations must actually bite: a 2-layer pipeline's hidden
/// image is ReLU-clamped, so the stacked output differs from chaining
/// the layers linearly.
#[test]
fn hidden_relu_changes_the_result() {
    let arch = ArchConfig::default();
    let base = run_cfg("gcn", 2, vec![]);
    let plan = ExecPlan::compile(&base).unwrap();
    let x = plan.make_input(2);
    let got = plan.simulate(&arch, true, Some(&x), 0).unwrap().output.unwrap();

    let graph = datasets::by_id("CR").unwrap().instantiate_typed(base.scale, 0, base.seed);
    let mut cur = x;
    for l in 0..2usize {
        let mut run_l = base.clone();
        run_l.layers = 1;
        run_l.seed = ModelSpec::layer_seed(base.seed, l);
        let lp = ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_l).unwrap();
        cur = lp.simulate(&arch, true, Some(&cur), 0).unwrap().output.unwrap();
        // deliberately NO activation between layers
    }
    assert_ne!(got, cur, "fixture too weak: hidden ReLU never clamped anything");
}

/// Warm multi-layer requests are allocation-free on the engine path:
/// the chain buffer and all frames pool across layers and runs.
#[test]
fn warm_depth3_engine_runs_are_allocation_free() {
    let arch = ArchConfig::default();
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m, 3, vec![])).unwrap();
        let x = plan.make_input(1);
        let mut scratch = ExecScratch::new();
        let cold = plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch).unwrap();
        let after_cold = scratch.alloc_events();
        assert!(after_cold > 0, "{m}: the cold run must size the pool");
        for _ in 0..3 {
            let warm = plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch).unwrap();
            assert_eq!(warm.output, cold.output, "{m}: warm runs must be bit-identical");
        }
        assert_eq!(
            scratch.alloc_events(),
            after_cold,
            "{m}: warm depth-3 runs must not grow the pool"
        );
    }
}

/// Warm multi-layer batches are allocation-free on the batched path too,
/// per exec-thread worker.
#[test]
fn warm_depth3_batches_are_allocation_free() {
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m, 3, vec![])).unwrap();
        let inputs: Vec<Vec<f32>> = (0..3).map(|s| plan.make_input(s)).collect();
        let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut scratch = BatchScratch::new();
        let cold = plan.execute_batch_with(&lanes, 4, &mut scratch).unwrap();
        let cold_total = scratch.alloc_events();
        let cold_per_worker = scratch.worker_alloc_events();
        assert!(cold_total > 0, "{m}: the cold batch must size the pools");
        for _ in 0..3 {
            let warm = plan.execute_batch_with(&lanes, 4, &mut scratch).unwrap();
            assert_eq!(warm, cold, "{m}: warm batches must be bit-identical");
        }
        assert_eq!(scratch.alloc_events(), cold_total, "{m}: warm depth-3 batch grew the pool");
        assert_eq!(
            scratch.worker_alloc_events(),
            cold_per_worker,
            "{m}: warm depth-3 batch grew a worker pool"
        );
    }
}

/// Non-uniform hidden widths flow through every layer of the stack
/// (engine + batched paths agree; dims land where the spec says).
#[test]
fn non_uniform_hidden_widths_execute_end_to_end() {
    let arch = ArchConfig::default();
    for m in ["gcn", "gat", "sage", "rgcn"] {
        let mut run = run_cfg(m, 3, vec![32, 8]);
        run.feat_in = 16;
        run.feat_out = 4;
        let plan = ExecPlan::compile(&run).unwrap();
        let dims: Vec<(u32, u32)> =
            plan.stages.iter().map(|s| (s.feat_in, s.feat_out)).collect();
        assert_eq!(dims, vec![(16, 32), (32, 8), (8, 4)], "{m}");
        assert_eq!(plan.dims.output_len, plan.dims.num_vertices as usize * 4);
        let x = plan.make_input(9);
        let engine = plan.simulate(&arch, true, Some(&x), 0).unwrap().output.unwrap();
        assert_eq!(engine.len(), plan.dims.output_len, "{m}");
        assert!(engine.iter().all(|v| v.is_finite()), "{m}");
        let mut scratch = BatchScratch::new();
        let batched = plan
            .execute_batch_with(&[x.as_slice()], 3, &mut scratch)
            .unwrap()
            .remove(0);
        assert_eq!(engine, batched, "{m}: engine and batched disagree at mixed widths");
    }
}

/// End-to-end through the coordinator: a 2-layer GCN/GAT/SAGE serves
/// through both the engine timing path and the batched functional path,
/// warm requests hit the plan cache, and batched outputs are
/// bit-identical to sequential ones.
#[test]
fn two_layer_models_serve_through_the_coordinator() {
    use zipper::config::ServingConfig;
    use zipper::plan::PlanCache;
    use std::sync::Arc;

    for m in ["gcn", "gat", "sage"] {
        let cache = Arc::new(PlanCache::new());
        let reqs: Vec<InferenceRequest> = (0..6)
            .map(|i| InferenceRequest { id: i, run: run_cfg(m, 2, vec![]), input_seed: i % 3 })
            .collect();
        let serve = |serving: ServingConfig| {
            let mut c = Coordinator::with_serving(
                ArchConfig::default(),
                2,
                serving,
                Arc::clone(&cache),
            );
            for r in &reqs {
                c.submit(r.clone());
            }
            let mut resp = c.drain();
            resp.sort_by_key(|r| r.id);
            resp
        };
        let seq = serve(ServingConfig { exec_threads: 1, max_batch: 1, ..Default::default() });
        let bat = serve(ServingConfig { exec_threads: 4, max_batch: 3, ..Default::default() });
        for (s, b) in seq.iter().zip(&bat) {
            assert!(s.error.is_none() && b.error.is_none(), "{m}: {:?} {:?}", s.error, b.error);
            assert_eq!(s.output_checksum, b.output_checksum, "{m} id={}", s.id);
            assert_eq!(s.sim_cycles, b.sim_cycles, "{m}");
            assert_eq!(b.layers.len(), 2, "{m}: depth-2 breakdown expected");
            assert!(b.plan_cache_hit, "{m}: second pass must be warm");
        }
    }
}
