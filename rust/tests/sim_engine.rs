//! Simulator engine behaviour through the public facade (moved out of
//! `sim/engine.rs` when the engine was split into scheduler / units /
//! exec submodules).

use zipper::compiler::{compile, OptLevel, Program};
use zipper::config::ArchConfig;
use zipper::graph::generators;
use zipper::models::{gat, gcn, ModelKind, WeightStore};
use zipper::sim::{ExecScratch, SimOptions, SimResult, Simulator, Workload};
use zipper::tiling::{tile, Reorder, TilingConfig, TilingMode};
use zipper::util::Rng;

fn run_model(m: ModelKind, opt: OptLevel, functional: bool) -> (SimResult, Program) {
    let arch = ArchConfig::default();
    let g = generators::power_law(300, 1500, 1.0, 1.0, if m.uses_etypes() { 3 } else { 0 }, 7);
    let tl = tile(
        &g,
        TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
    );
    let prog = compile(&m.build(), opt).unwrap();
    let (fi, fo) = if m.requires_square() { (16, 16) } else { (16, 8) };
    let ws = WeightStore::synthesize(&m.build(), fi, fo, 5);
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..300 * fi as usize).map(|_| rng.next_f32_sym() * 0.5).collect();
    let wl = Workload {
        program: &prog,
        tiling: &tl,
        weights: &ws,
        feat_in: fi,
        feat_out: fo,
        x: functional.then_some(x.as_slice()),
        kernels: Default::default(),
    };
    let res = Simulator::new(&arch, &wl, SimOptions { functional, ..Default::default() })
        .run()
        .unwrap_or_else(|e| panic!("{}: {e}", m.name()));
    (res, prog)
}

#[test]
fn all_models_simulate_to_completion() {
    for m in ModelKind::ALL {
        let (res, _) = run_model(m, OptLevel::E2v, false);
        assert!(res.cycles > 0, "{}", m.name());
        assert!(res.instructions > 0);
        assert!(res.dram_read_bytes > 0);
    }
}

#[test]
fn functional_gcn_matches_direct_computation() {
    let (res, _) = run_model(ModelKind::Gcn, OptLevel::E2v, true);
    let out = res.output.unwrap();
    // recompute directly: out = A^T·(x W) summed over in-edges
    let g = generators::power_law(300, 1500, 1.0, 1.0, 0, 7);
    let ws = WeightStore::synthesize(&gcn(), 16, 8, 5);
    let w = &ws.tensors[0];
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..300 * 16).map(|_| rng.next_f32_sym() * 0.5).collect();
    // h = x @ w  (E2V order); out[d] = Σ_{s∈in(d)} h[s]
    let mut h = vec![0.0f32; 300 * 8];
    for v in 0..300usize {
        for kk in 0..16usize {
            let xv = x[v * 16 + kk];
            for n in 0..8usize {
                h[v * 8 + n] += xv * w.data[kk * 8 + n];
            }
        }
    }
    let mut expect = vec![0.0f32; 300 * 8];
    for d in 0..300u32 {
        for &s in g.in_neighbors(d) {
            for n in 0..8usize {
                expect[d as usize * 8 + n] += h[s as usize * 8 + n];
            }
        }
    }
    for (a, b) in out.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn naive_and_e2v_agree_functionally() {
    for m in [ModelKind::Gat, ModelKind::Sage] {
        let (a, _) = run_model(m, OptLevel::None, true);
        let (b, _) = run_model(m, OptLevel::E2v, true);
        let (oa, ob) = (a.output.unwrap(), b.output.unwrap());
        let mut max_err = 0.0f32;
        for (x, y) in oa.iter().zip(&ob) {
            max_err = max_err.max((x - y).abs());
        }
        assert!(max_err < 1e-3, "{}: max err {max_err}", m.name());
    }
}

#[test]
fn e2v_is_faster_for_gat() {
    let (naive, _) = run_model(ModelKind::Gat, OptLevel::None, false);
    let (opt, _) = run_model(ModelKind::Gat, OptLevel::E2v, false);
    assert!(opt.cycles < naive.cycles, "E2V {} !< naive {}", opt.cycles, naive.cycles);
}

#[test]
fn more_streams_dont_break_correctness() {
    let mut arch = ArchConfig::default();
    arch.s_streams = 8;
    arch.e_streams = 8;
    let g = generators::power_law(200, 1000, 1.0, 1.0, 0, 3);
    let tl = tile(
        &g,
        TilingConfig {
            dst_part: 32,
            src_part: 32,
            mode: TilingMode::Sparse,
            reorder: Reorder::None,
            threads: 1,
        },
    );
    let prog = compile(&gcn(), OptLevel::E2v).unwrap();
    let ws = WeightStore::synthesize(&gcn(), 8, 8, 1);
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..200 * 8).map(|_| rng.next_f32_sym()).collect();
    let wl = Workload {
        program: &prog,
        tiling: &tl,
        weights: &ws,
        feat_in: 8,
        feat_out: 8,
        x: Some(&x),
        kernels: Default::default(),
    };
    let res = Simulator::new(&arch, &wl, SimOptions { functional: true, ..Default::default() })
        .run()
        .unwrap();
    assert!(res.output.unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn scratch_reuse_matches_fresh_runs() {
    // the serving hot path: one scratch, many runs — results must be
    // bit-identical to fresh-scratch runs, across different models
    let mut scratch = ExecScratch::new();
    for m in [ModelKind::Gcn, ModelKind::Gat, ModelKind::Sage] {
        let arch = ArchConfig::default();
        let g = generators::power_law(120, 700, 1.0, 1.0, 0, 21);
        let tl = tile(
            &g,
            TilingConfig {
                dst_part: 32,
                src_part: 32,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
        );
        let prog = compile(&m.build(), OptLevel::E2v).unwrap();
        let ws = WeightStore::synthesize(&m.build(), 8, 8, 3);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..120 * 8).map(|_| rng.next_f32_sym()).collect();
        let wl = Workload {
            program: &prog,
            tiling: &tl,
            weights: &ws,
            feat_in: 8,
            feat_out: 8,
            x: Some(&x),
            kernels: Default::default(),
        };
        let sim = Simulator::new(&arch, &wl, SimOptions { functional: true, ..Default::default() });
        let fresh = sim.run().unwrap();
        let reused = sim.run_with(&mut scratch).unwrap();
        assert_eq!(fresh.cycles, reused.cycles, "{}", m.name());
        assert_eq!(fresh.output.unwrap(), reused.output.unwrap(), "{}", m.name());
    }
}

#[test]
fn trace_produces_samples() {
    let arch = ArchConfig::default();
    let g = generators::power_law(300, 3000, 1.1, 1.1, 0, 9);
    let tl = tile(&g, TilingConfig::default());
    let prog = compile(&gat(), OptLevel::E2v).unwrap();
    let ws = WeightStore::synthesize(&gat(), 32, 32, 1);
    let wl = Workload {
        program: &prog,
        tiling: &tl,
        weights: &ws,
        feat_in: 32,
        feat_out: 32,
        x: None,
        kernels: Default::default(),
    };
    let res = Simulator::new(&arch, &wl, SimOptions { functional: false, trace_window: 256, ..Default::default() })
        .run()
        .unwrap();
    assert!(!res.trace.is_empty());
    // GAT must show multiple phases
    let phases: std::collections::HashSet<&str> =
        res.trace.iter().map(|s| s.phase.tag()).collect();
    assert!(phases.len() >= 2, "phases: {phases:?}");
}
