//! Differential fuzzing for the pipeline optimizer (DESIGN.md §3.7).
//!
//! The optimizer's contract is *bit-exactness*: for every model × depth
//! × pass subset, an optimized plan must produce byte-identical outputs
//! to the plain `OptLevel::E2v` plan on BOTH executors — the cycle
//! engine (`simulate_with`, functional) and the batched tile-parallel
//! path (`execute_batch_with`) at 1 and 4 exec threads. On top of that,
//! per-pass instruction counts must be monotonically non-increasing (no
//! pass may grow the pipeline).
//!
//! The sweep is seeded: `OPT_FUZZ_SEED=<n>` re-randomizes the dataset
//! seed and input seeds (CI runs one fixed-seed pass and one randomized
//! soak); unset, the seed is fixed so failures reproduce exactly.

use zipper::compiler::PassSet;
use zipper::config::{ArchConfig, RunConfig};
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];

fn fuzz_seed() -> u64 {
    std::env::var("OPT_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
}

fn run_cfg(model: &str, layers: u32, passes: PassSet, seed: u64) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 32,
        feat_in: 8,
        feat_out: 8,
        layers,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes,
        functional: true,
        seed,
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

/// The full differential sweep: {gcn,gat,sage,ggnn,rgcn} × depths
/// {1,2,3} × all 16 pass subsets, each pinned bit-exact against the
/// `PassSet::none()` (plain E2v) plan on both executors.
#[test]
fn every_pass_subset_is_bit_exact_on_both_executors() {
    let arch = ArchConfig::default();
    let seed = fuzz_seed();
    for model in MODELS {
        for depth in [1u32, 2, 3] {
            let baseline =
                ExecPlan::compile(&run_cfg(model, depth, PassSet::none(), seed)).unwrap();
            let inputs: Vec<Vec<f32>> =
                (0..2u64).map(|l| baseline.make_input(seed ^ (l + 11))).collect();
            let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let engine_ref: Vec<Vec<f32>> = inputs
                .iter()
                .map(|x| {
                    baseline.simulate(&arch, true, Some(x), 0).unwrap().output.unwrap()
                })
                .collect();
            let base_instrs: usize = baseline
                .stages
                .iter()
                .map(|s| s.program.instruction_count())
                .sum();

            for passes in PassSet::every_subset() {
                let tag = format!("{model} depth={depth} passes={passes} seed={seed}");
                let plan =
                    ExecPlan::compile(&run_cfg(model, depth, passes, seed)).unwrap();

                // engine path: bit-exact per lane
                for (x, want) in inputs.iter().zip(&engine_ref) {
                    let got =
                        plan.simulate(&arch, true, Some(x), 0).unwrap().output.unwrap();
                    assert_eq!(&got, want, "{tag}: engine output diverged");
                }

                // batched path: bit-exact at 1 and 4 exec threads
                for threads in [1usize, 4] {
                    let mut scratch = BatchScratch::new();
                    let got =
                        plan.execute_batch_with(&lanes, threads, &mut scratch).unwrap();
                    for (lane, (g, want)) in got.iter().zip(&engine_ref).enumerate() {
                        assert_eq!(
                            g, want,
                            "{tag}: run_batch threads={threads} lane={lane} diverged"
                        );
                    }
                }

                // per-pass instruction counts monotonically non-increasing
                if passes.is_empty() {
                    assert!(plan.opt_report.is_none(), "{tag}");
                } else {
                    let rep = plan.opt_report.as_ref().expect(&tag);
                    assert_eq!(rep.instructions_before, base_instrs, "{tag}");
                    let mut prev = rep.instructions_before;
                    for p in &rep.passes {
                        assert!(
                            p.instructions_after <= prev,
                            "{tag}: pass {} grew the pipeline ({} -> {})",
                            p.pass,
                            prev,
                            p.instructions_after
                        );
                        prev = p.instructions_after;
                    }
                    let total: usize = plan
                        .stages
                        .iter()
                        .map(|s| s.program.instruction_count())
                        .sum();
                    assert_eq!(rep.instructions_after(), total, "{tag}");
                }
            }
        }
    }
}

/// The ISSUE acceptance shape, pinned under the fuzz seed too: all
/// passes on a depth-3 GCN strictly shrink the pipeline, and the
/// attribution names every pass in its fixed execution order.
#[test]
fn all_passes_depth3_gcn_strictly_shrinks() {
    let seed = fuzz_seed();
    let baseline = ExecPlan::compile(&run_cfg("gcn", 3, PassSet::none(), seed)).unwrap();
    let optimized = ExecPlan::compile(&run_cfg("gcn", 3, PassSet::all(), seed)).unwrap();
    let count = |p: &ExecPlan| {
        p.stages.iter().map(|s| s.program.instruction_count()).sum::<usize>()
    };
    assert!(count(&optimized) < count(&baseline));
    let rep = optimized.opt_report.as_ref().unwrap();
    let order: Vec<&str> = rep.passes.iter().map(|p| p.pass).collect();
    assert_eq!(order, ["load_elim", "fuse", "hoist", "dbe"]);
    let sum = |f: fn(&zipper::compiler::OptReport) -> usize| {
        rep.passes.iter().map(|p| f(&p.report)).sum::<usize>()
    };
    assert!(sum(|r| r.removed) >= 2, "cross-layer LD.EDGE elimination must fire");
    assert!(sum(|r| r.fused) >= 2, "both hidden-layer ReLUs must fuse");
    assert!(sum(|r| r.freed) >= 2, "fusion orphans must be swept");
}

/// Pass-subset plans must never alias in the plan cache: 16 subsets ×
/// one model/depth = 16 distinct entries.
#[test]
fn pass_subsets_never_alias_in_the_cache() {
    use zipper::plan::PlanCache;
    let cache = PlanCache::new();
    let seed = fuzz_seed();
    for passes in PassSet::every_subset() {
        let (_, hit) = cache.get_or_compile(&run_cfg("gcn", 2, passes, seed)).unwrap();
        assert!(!hit, "passes={passes} aliased a previous subset");
    }
    assert_eq!(cache.stats().entries, 16);
    for passes in PassSet::every_subset() {
        let (_, hit) = cache.get_or_compile(&run_cfg("gcn", 2, passes, seed)).unwrap();
        assert!(hit, "passes={passes} must be warm on the second pass");
    }
}
