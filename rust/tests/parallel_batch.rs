//! Determinism, equivalence, and allocation tests for the tile-parallel
//! batched functional executor (`sim::parallel`) and the coordinator's
//! batched serving path: outputs must be bit-identical to the sequential
//! path for every (exec_threads, max_batch) combination, batched timing
//! must match the engine, and warm batches must not grow any worker
//! thread's pool.

use std::sync::Arc;
use zipper::config::{ArchConfig, RunConfig, ServingConfig};
use zipper::coordinator::{Coordinator, InferenceRequest, InferenceResponse};
use zipper::plan::{ExecPlan, PlanCache};
use zipper::sim::parallel::BatchScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];
const THREADS: [usize; 3] = [1, 2, 4];
const BATCHES: [usize; 3] = [1, 3, 8];

fn run_cfg(model: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        functional: true,
        seed: 3,
        serving: Default::default(),
    }
}

#[test]
fn tile_parallel_outputs_bit_identical_for_all_threads_and_batches() {
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let inputs: Vec<Vec<f32>> = (0..8).map(|s| plan.make_input(s)).collect();
        // the sequential path: one lane at a time, one exec thread
        let mut seq = BatchScratch::new();
        let expected: Vec<Vec<f32>> = inputs
            .iter()
            .map(|x| {
                plan.execute_batch_with(&[x.as_slice()], 1, &mut seq)
                    .unwrap()
                    .remove(0)
            })
            .collect();
        for threads in THREADS {
            for batch in BATCHES {
                let mut scratch = BatchScratch::new();
                let mut got: Vec<Vec<f32>> = Vec::new();
                for chunk in inputs.chunks(batch) {
                    let lanes: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
                    got.extend(plan.execute_batch_with(&lanes, threads, &mut scratch).unwrap());
                }
                assert_eq!(got.len(), expected.len());
                for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
                    assert_eq!(g, e, "{m} threads={threads} batch={batch} lane={i}");
                }
            }
        }
    }
}

#[test]
fn parallel_executor_matches_engine_functional_closely() {
    // the canonical tile-ordered reduction uses a different float
    // association than the discrete-event engine's schedule-dependent
    // gather order, so this is a tolerance check, not bit equality
    let arch = ArchConfig::default();
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let x = plan.make_input(5);
        let engine = plan
            .simulate(&arch, true, Some(&x), 0)
            .unwrap()
            .output
            .unwrap();
        let mut scratch = BatchScratch::new();
        let par = plan
            .execute_batch_with(&[&x], 2, &mut scratch)
            .unwrap()
            .remove(0);
        assert_eq!(engine.len(), par.len(), "{m}");
        for (i, (a, b)) in engine.iter().zip(&par).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "{m} row {i}: engine {a} vs parallel {b}"
            );
        }
    }
}

#[test]
fn bad_input_length_is_reported() {
    let plan = ExecPlan::compile(&run_cfg("gcn")).unwrap();
    let short = vec![0.0f32; 3];
    let mut scratch = BatchScratch::new();
    let err = plan
        .execute_batch_with(&[short.as_slice()], 2, &mut scratch)
        .unwrap_err();
    assert!(err.contains("input embedding size"), "{err}");
    // empty batches are a no-op, not an error
    assert!(plan
        .execute_batch_with(&[], 2, &mut scratch)
        .unwrap()
        .is_empty());
}

#[test]
fn warm_batches_do_not_grow_any_worker_pool() {
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let inputs: Vec<Vec<f32>> = (0..3).map(|s| plan.make_input(s)).collect();
        let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut scratch = BatchScratch::new();
        plan.execute_batch_with(&lanes, 4, &mut scratch).unwrap();
        let cold_total = scratch.alloc_events();
        let cold_per_worker = scratch.worker_alloc_events();
        assert!(cold_total > 0, "{m}: the cold batch must size the pools");
        for _ in 0..3 {
            plan.execute_batch_with(&lanes, 4, &mut scratch).unwrap();
        }
        assert_eq!(
            scratch.alloc_events(),
            cold_total,
            "{m}: warm batches must not grow the pool"
        );
        assert_eq!(
            scratch.worker_alloc_events(),
            cold_per_worker,
            "{m}: warm batches must not grow any worker thread's pool"
        );
    }
}

#[test]
fn one_scratch_serves_all_plans_bit_identically() {
    // cross-plan pooling hazard: run all five models through ONE batch
    // scratch and compare against fresh-scratch outputs
    let plans: Vec<ExecPlan> = MODELS
        .iter()
        .map(|m| ExecPlan::compile(&run_cfg(m)).unwrap())
        .collect();
    let mut shared = BatchScratch::new();
    for round in 0..2u64 {
        for (plan, m) in plans.iter().zip(MODELS) {
            let inputs: Vec<Vec<f32>> = (0..3).map(|s| plan.make_input(round + s)).collect();
            let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let mut fresh = BatchScratch::new();
            let want = plan.execute_batch_with(&lanes, 2, &mut fresh).unwrap();
            let got = plan.execute_batch_with(&lanes, 2, &mut shared).unwrap();
            assert_eq!(got, want, "{m} round {round}");
        }
    }
}

fn serve(
    serving: ServingConfig,
    cache: &Arc<PlanCache>,
    reqs: &[InferenceRequest],
) -> Vec<InferenceResponse> {
    let mut c =
        Coordinator::with_serving(ArchConfig::default(), 2, serving, Arc::clone(cache));
    for r in reqs {
        c.submit(r.clone());
    }
    let mut resp = c.drain();
    resp.sort_by_key(|r| r.id);
    resp
}

#[test]
fn batched_serving_bit_identical_to_sequential_for_all_combinations() {
    // two plans interleaved so the BatchPlanner actually has to group
    let reqs: Vec<InferenceRequest> = (0..8)
        .map(|i| InferenceRequest {
            id: i,
            run: run_cfg(if i % 2 == 0 { "gcn" } else { "gat" }),
            input_seed: i,
        })
        .collect();
    let cache = Arc::new(PlanCache::new());
    let sequential = serve(ServingConfig { exec_threads: 1, max_batch: 1 }, &cache, &reqs);
    for r in &sequential {
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.output_checksum.is_some());
    }
    for threads in THREADS {
        for batch in BATCHES {
            let serving =
                ServingConfig { exec_threads: threads as u32, max_batch: batch as u32 };
            let got = serve(serving, &cache, &reqs);
            assert_eq!(got.len(), sequential.len());
            for (g, s) in got.iter().zip(&sequential) {
                assert!(g.error.is_none(), "{:?}", g.error);
                assert_eq!(
                    g.output_checksum, s.output_checksum,
                    "threads={threads} batch={batch} id={}",
                    g.id
                );
                assert_eq!(g.sim_cycles, s.sim_cycles, "timing must not depend on batching");
                assert!(g.batch_size >= 1 && g.batch_size <= batch);
            }
        }
    }
}

#[test]
fn all_models_batch_identically_through_the_coordinator() {
    // every model: 6 same-plan functional requests batched 3-at-a-time
    // across 4 exec threads must reproduce the sequential checksums
    for m in MODELS {
        let reqs: Vec<InferenceRequest> = (0..6)
            .map(|i| InferenceRequest { id: i, run: run_cfg(m), input_seed: i % 2 })
            .collect();
        let cache = Arc::new(PlanCache::new());
        let seq = serve(ServingConfig { exec_threads: 1, max_batch: 1 }, &cache, &reqs);
        let bat = serve(ServingConfig { exec_threads: 4, max_batch: 3 }, &cache, &reqs);
        for (s, b) in seq.iter().zip(&bat) {
            assert!(s.error.is_none() && b.error.is_none());
            assert_eq!(s.output_checksum, b.output_checksum, "{m} id={}", s.id);
        }
        // same input seed ⇒ same checksum, regardless of batch position
        assert_eq!(bat[0].output_checksum, bat[2].output_checksum, "{m}");
        assert_eq!(bat[1].output_checksum, bat[3].output_checksum, "{m}");
    }
}
