//! Determinism, equivalence, and allocation tests for the tile-parallel
//! batched functional executor (`sim::parallel`) and the coordinator's
//! batched serving path: outputs must be bit-identical to the sequential
//! path for every (exec_threads, max_batch) combination, **bit-identical
//! to the discrete-event engine's functional output** (both paths run
//! the shared `sim::dispatch` core and fold gathers in the same tile
//! order), batched timing must match the engine, and warm batches must
//! not grow any worker thread's pool.

use std::sync::Arc;
use zipper::compiler::{compile, OptLevel, Program};
use zipper::config::{ArchConfig, RunConfig, ServingConfig};
use zipper::coordinator::{Coordinator, InferenceRequest, InferenceResponse};
use zipper::graph::generators;
use zipper::isa::{Dim, ElwUnary, Instr, LdTarget, StreamClass};
use zipper::models::{ModelKind, WeightStore};
use zipper::plan::{ExecPlan, PlanCache};
use zipper::sim::parallel::{run_batch, BatchScratch};
use zipper::sim::{SimOptions, Simulator, Workload};
use zipper::tiling::{tile, Reorder, Tiling, TilingConfig, TilingMode};
use zipper::util::Rng;

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];
const THREADS: [usize; 3] = [1, 2, 4];
const BATCHES: [usize; 3] = [1, 3, 8];

fn run_cfg(model: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        layers: 1,
        hidden: Vec::new(),
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

#[test]
fn tile_parallel_outputs_bit_identical_for_all_threads_and_batches() {
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let inputs: Vec<Vec<f32>> = (0..8).map(|s| plan.make_input(s)).collect();
        // the sequential path: one lane at a time, one exec thread
        let mut seq = BatchScratch::new();
        let expected: Vec<Vec<f32>> = inputs
            .iter()
            .map(|x| {
                plan.execute_batch_with(&[x.as_slice()], 1, &mut seq)
                    .unwrap()
                    .remove(0)
            })
            .collect();
        for threads in THREADS {
            for batch in BATCHES {
                let mut scratch = BatchScratch::new();
                let mut got: Vec<Vec<f32>> = Vec::new();
                for chunk in inputs.chunks(batch) {
                    let lanes: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
                    got.extend(plan.execute_batch_with(&lanes, threads, &mut scratch).unwrap());
                }
                assert_eq!(got.len(), expected.len());
                for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
                    assert_eq!(g, e, "{m} threads={threads} batch={batch} lane={i}");
                }
            }
        }
    }
}

#[test]
fn batched_path_is_bit_exact_with_the_engine() {
    // Both paths execute the single `sim::dispatch` instruction core and
    // both defer GTHR to the same ascending-tile-order fold at the
    // partition wait boundary, so they perform literally the same float
    // operations in the same order. This used to be a 1e-3 tolerance
    // check (the engine's gather order followed the simulated schedule);
    // the shared core makes it exact equality — for every model, thread
    // count, and batch grouping.
    let arch = ArchConfig::default();
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let inputs: Vec<Vec<f32>> = (0..8).map(|s| plan.make_input(s)).collect();
        let engine: Vec<Vec<f32>> = inputs
            .iter()
            .map(|x| plan.simulate(&arch, true, Some(x), 0).unwrap().output.unwrap())
            .collect();
        for threads in THREADS {
            for batch in BATCHES {
                let mut scratch = BatchScratch::new();
                let mut got: Vec<Vec<f32>> = Vec::new();
                for chunk in inputs.chunks(batch) {
                    let lanes: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
                    got.extend(plan.execute_batch_with(&lanes, threads, &mut scratch).unwrap());
                }
                assert_eq!(got.len(), engine.len());
                for (i, (g, e)) in got.iter().zip(&engine).enumerate() {
                    assert_eq!(
                        g, e,
                        "{m} threads={threads} batch={batch} lane={i}: \
                         engine and batched outputs must be bit-exact"
                    );
                }
            }
        }
    }
}

#[test]
fn multi_layer_batched_path_bit_exact_with_engine_across_threads_and_batches() {
    // the stacked-layer pipeline inherits the determinism contract: for
    // depths 2 and 3, engine and batched outputs stay bit-exact for
    // every thread count and batch grouping
    let arch = ArchConfig::default();
    for m in MODELS {
        for depth in [2u32, 3] {
            let mut run = run_cfg(m);
            run.layers = depth;
            let plan = ExecPlan::compile(&run).unwrap();
            let inputs: Vec<Vec<f32>> = (0..8).map(|s| plan.make_input(s)).collect();
            let engine: Vec<Vec<f32>> = inputs
                .iter()
                .map(|x| plan.simulate(&arch, true, Some(x), 0).unwrap().output.unwrap())
                .collect();
            for threads in THREADS {
                for batch in BATCHES {
                    let mut scratch = BatchScratch::new();
                    let mut got: Vec<Vec<f32>> = Vec::new();
                    for chunk in inputs.chunks(batch) {
                        let lanes: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
                        got.extend(
                            plan.execute_batch_with(&lanes, threads, &mut scratch).unwrap(),
                        );
                    }
                    for (i, (g, e)) in got.iter().zip(&engine).enumerate() {
                        assert_eq!(
                            g, e,
                            "{m} depth={depth} threads={threads} batch={batch} lane={i}"
                        );
                    }
                }
            }
        }
    }
}

// ---- hand-patched-program fixtures (aliasing + layout regression) ------

fn small_tiling(g: &zipper::graph::Graph) -> Tiling {
    tile(
        g,
        TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
    )
}

/// Recompute a relative jump offset after inserting one instruction at
/// `at` into the function that holds it: jumps spanning the insertion
/// point stretch by one, others are unchanged.
fn patched_jump(off: i32, j: usize, at: usize) -> i32 {
    let t_old = j as i64 + off as i64;
    let j_new = j as i64 + (j >= at) as i64;
    let t_new = t_old + (t_old >= at as i64) as i64;
    (t_new - j_new) as i32
}

/// Insert `instr` at `at`, patching every relative control offset
/// (JUMP, FCH.TILE on_empty) so the stream protocol stays intact.
fn insert_patched(func: &mut Vec<Instr>, at: usize, instr: Instr) {
    for (j, ins) in func.iter_mut().enumerate() {
        match ins {
            Instr::Jump(off) => *off = patched_jump(*off, j, at),
            Instr::FchTile { on_empty } => *on_empty = patched_jump(*on_empty, j, at),
            _ => {}
        }
    }
    func.insert(at, instr);
}

#[test]
fn aliased_in_place_ops_execute_identically_on_engine_and_batched_path() {
    // Regression for the tentpole's aliasing fix: compiler-produced GCN
    // with a `src == dst` in-place ReLU patched into BOTH phases — the
    // tile phase (right after LD.SRC, covering the worker-frame adapter)
    // and the dFunction post phase (on the output buffer before ST.DST,
    // covering the partition adapters). Historically every path failed
    // this with a spurious "buffer bN unset".
    let m = ModelKind::Gcn;
    let g = generators::power_law(200, 1000, 1.0, 1.0, 0, 13);
    let tl = small_tiling(&g);
    let (fi, fo) = (16u32, 8u32);
    let ws = WeightStore::synthesize(&m.build(), fi, fo, 5);
    let mut prog = compile(&m.build(), OptLevel::E2v).unwrap();

    let ld_at = prog
        .s_func
        .iter()
        .position(|i| matches!(i, Instr::Ld { target: LdTarget::Src, .. }))
        .expect("sFunction has LD.SRC");
    let src_buf = match &prog.s_func[ld_at] {
        Instr::Ld { dst, .. } => *dst,
        _ => unreachable!(),
    };
    insert_patched(
        &mut prog.s_func,
        ld_at + 1,
        Instr::ElwU {
            op: ElwUnary::Relu,
            src: src_buf,
            dst: src_buf,
            rows: Dim::TileSrc,
            cols: Dim::FeatIn,
        },
    );
    let st_at = prog
        .d_func
        .iter()
        .position(|i| matches!(i, Instr::St { .. }))
        .expect("dFunction has ST.DST");
    insert_patched(
        &mut prog.d_func,
        st_at,
        Instr::ElwU {
            op: ElwUnary::Relu,
            src: prog.output_buf,
            dst: prog.output_buf,
            rows: Dim::PartDst,
            cols: Dim::FeatOut,
        },
    );

    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..200 * fi as usize).map(|_| rng.next_f32_sym() * 0.5).collect();
    let wl = Workload {
        program: &prog,
        tiling: &tl,
        weights: &ws,
        feat_in: fi,
        feat_out: fo,
        x: Some(&x),
        kernels: Default::default(),
    };
    let arch = ArchConfig::default();
    let engine = Simulator::new(&arch, &wl, SimOptions { functional: true, ..Default::default() })
        .run()
        .expect("aliased ops must execute on the engine")
        .output
        .unwrap();
    let mut scratch = BatchScratch::new();
    let batched = run_batch(&wl, &[&x], 3, &mut scratch)
        .expect("aliased ops must execute on the batched path")
        .remove(0);
    assert_eq!(engine, batched, "aliased program diverged between the two paths");
    // the trailing in-place relu really ran: outputs are clamped at 0 …
    assert!(engine.iter().all(|&v| v >= 0.0));
    // … and not vacuously — the unpatched program produces negatives
    let base_prog = compile(&m.build(), OptLevel::E2v).unwrap();
    let wl0 = Workload { program: &base_prog, ..wl };
    let base = run_batch(&wl0, &[&x], 1, &mut scratch).unwrap().remove(0);
    assert!(base.iter().any(|&v| v < 0.0), "fixture too weak: baseline has no negatives");
}

#[test]
fn malformed_d_function_layouts_are_structured_errors() {
    // `run_batch` used to slice `d[1..sig]` unconditionally, silently
    // dropping instruction 0 if it was ever not FCH.PTT; now every
    // layout violation is a descriptive error.
    let m = ModelKind::Gcn;
    let g = generators::power_law(60, 240, 1.0, 1.0, 0, 3);
    let tl = small_tiling(&g);
    let ws = WeightStore::synthesize(&m.build(), 8, 8, 1);
    let base = compile(&m.build(), OptLevel::E2v).unwrap();
    let x = vec![0.25f32; 60 * 8];
    let mut scratch = BatchScratch::new();
    let mut run = |prog: &Program| {
        let wl = Workload {
            program: prog,
            tiling: &tl,
            weights: &ws,
            feat_in: 8,
            feat_out: 8,
            x: None,
            kernels: Default::default(),
        };
        run_batch(&wl, &[&x], 1, &mut scratch)
    };

    let mut p = base.clone();
    p.d_func[0] = Instr::Halt;
    let err = run(&p).unwrap_err();
    assert!(err.contains("expected FCH.PTT at instruction 0"), "{err}");

    let mut p = base.clone();
    p.d_func
        .retain(|i| !matches!(i, Instr::Signal { class: StreamClass::S }));
    let err = run(&p).unwrap_err();
    assert!(err.contains("missing SIGNAL.S"), "{err}");

    let mut p = base.clone();
    let sig = p
        .d_func
        .iter()
        .position(|i| matches!(i, Instr::Signal { class: StreamClass::S }))
        .unwrap();
    let wait = p.d_func.iter().position(|i| matches!(i, Instr::Wait { .. })).unwrap();
    p.d_func.swap(sig, wait);
    let err = run(&p).unwrap_err();
    assert!(err.contains("out of order"), "{err}");

    // the untouched program still runs through the same scratch
    assert!(run(&base).is_ok());
}

#[test]
fn bad_input_length_is_reported() {
    let plan = ExecPlan::compile(&run_cfg("gcn")).unwrap();
    let short = vec![0.0f32; 3];
    let mut scratch = BatchScratch::new();
    let err = plan
        .execute_batch_with(&[short.as_slice()], 2, &mut scratch)
        .unwrap_err();
    assert!(err.contains("input embedding size"), "{err}");
    // empty batches are a no-op, not an error
    assert!(plan
        .execute_batch_with(&[], 2, &mut scratch)
        .unwrap()
        .is_empty());
}

#[test]
fn warm_batches_do_not_grow_any_worker_pool() {
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let inputs: Vec<Vec<f32>> = (0..3).map(|s| plan.make_input(s)).collect();
        let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut scratch = BatchScratch::new();
        plan.execute_batch_with(&lanes, 4, &mut scratch).unwrap();
        let cold_total = scratch.alloc_events();
        let cold_per_worker = scratch.worker_alloc_events();
        assert!(cold_total > 0, "{m}: the cold batch must size the pools");
        for _ in 0..3 {
            plan.execute_batch_with(&lanes, 4, &mut scratch).unwrap();
        }
        assert_eq!(
            scratch.alloc_events(),
            cold_total,
            "{m}: warm batches must not grow the pool"
        );
        assert_eq!(
            scratch.worker_alloc_events(),
            cold_per_worker,
            "{m}: warm batches must not grow any worker thread's pool"
        );
    }
}

#[test]
fn one_scratch_serves_all_plans_bit_identically() {
    // cross-plan pooling hazard: run all five models through ONE batch
    // scratch and compare against fresh-scratch outputs
    let plans: Vec<ExecPlan> = MODELS
        .iter()
        .map(|m| ExecPlan::compile(&run_cfg(m)).unwrap())
        .collect();
    let mut shared = BatchScratch::new();
    for round in 0..2u64 {
        for (plan, m) in plans.iter().zip(MODELS) {
            let inputs: Vec<Vec<f32>> = (0..3).map(|s| plan.make_input(round + s)).collect();
            let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let mut fresh = BatchScratch::new();
            let want = plan.execute_batch_with(&lanes, 2, &mut fresh).unwrap();
            let got = plan.execute_batch_with(&lanes, 2, &mut shared).unwrap();
            assert_eq!(got, want, "{m} round {round}");
        }
    }
}

fn serve(
    serving: ServingConfig,
    cache: &Arc<PlanCache>,
    reqs: &[InferenceRequest],
) -> Vec<InferenceResponse> {
    let mut c =
        Coordinator::with_serving(ArchConfig::default(), 2, serving, Arc::clone(cache));
    for r in reqs {
        c.submit(r.clone());
    }
    let mut resp = c.drain();
    resp.sort_by_key(|r| r.id);
    resp
}

#[test]
fn batched_serving_bit_identical_to_sequential_for_all_combinations() {
    // two plans interleaved so the BatchPlanner actually has to group
    let reqs: Vec<InferenceRequest> = (0..8)
        .map(|i| InferenceRequest {
            id: i,
            run: run_cfg(if i % 2 == 0 { "gcn" } else { "gat" }),
            input_seed: i,
        })
        .collect();
    let cache = Arc::new(PlanCache::new());
    let sequential = serve(
        ServingConfig { exec_threads: 1, max_batch: 1, ..Default::default() },
        &cache,
        &reqs,
    );
    for r in &sequential {
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.output_checksum.is_some());
    }
    for threads in THREADS {
        for batch in BATCHES {
            let serving = ServingConfig {
                exec_threads: threads as u32,
                max_batch: batch as u32,
                ..Default::default()
            };
            let got = serve(serving, &cache, &reqs);
            assert_eq!(got.len(), sequential.len());
            for (g, s) in got.iter().zip(&sequential) {
                assert!(g.error.is_none(), "{:?}", g.error);
                assert_eq!(
                    g.output_checksum, s.output_checksum,
                    "threads={threads} batch={batch} id={}",
                    g.id
                );
                assert_eq!(g.sim_cycles, s.sim_cycles, "timing must not depend on batching");
                assert!(g.batch_size >= 1 && g.batch_size <= batch);
            }
        }
    }
}

#[test]
fn all_models_batch_identically_through_the_coordinator() {
    // every model: 6 same-plan functional requests batched 3-at-a-time
    // across 4 exec threads must reproduce the sequential checksums
    for m in MODELS {
        let reqs: Vec<InferenceRequest> = (0..6)
            .map(|i| InferenceRequest { id: i, run: run_cfg(m), input_seed: i % 2 })
            .collect();
        let cache = Arc::new(PlanCache::new());
        let seq = serve(
            ServingConfig { exec_threads: 1, max_batch: 1, ..Default::default() },
            &cache,
            &reqs,
        );
        let bat = serve(
            ServingConfig { exec_threads: 4, max_batch: 3, ..Default::default() },
            &cache,
            &reqs,
        );
        for (s, b) in seq.iter().zip(&bat) {
            assert!(s.error.is_none() && b.error.is_none());
            assert_eq!(s.output_checksum, b.output_checksum, "{m} id={}", s.id);
        }
        // same input seed ⇒ same checksum, regardless of batch position
        assert_eq!(bat[0].output_checksum, bat[2].output_checksum, "{m}");
        assert_eq!(bat[1].output_checksum, bat[3].output_checksum, "{m}");
    }
}
