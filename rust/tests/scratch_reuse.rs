//! Differential tests for the pooled `ExecScratch`: reusing one scratch
//! across runs — and across *different plans* — must be bit-identical
//! to fresh-scratch runs (stale-capacity / stale-shape bugs show up
//! here), and the warm path must not grow the pool at all.

use zipper::config::{ArchConfig, RunConfig};
use zipper::plan::ExecPlan;
use zipper::sim::ExecScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];

fn run_cfg(model: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        layers: 1,
        hidden: Vec::new(),
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

#[test]
fn reused_scratch_is_bit_identical_across_runs() {
    let arch = ArchConfig::default();
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let x = plan.make_input(9);
        let fresh = plan.simulate(&arch, true, Some(&x), 0).unwrap();
        let expect = fresh.output.unwrap();
        let mut scratch = ExecScratch::new();
        for round in 0..3 {
            let res = plan
                .simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                .unwrap();
            assert_eq!(res.cycles, fresh.cycles, "{m} round {round}");
            assert_eq!(res.output.unwrap(), expect, "{m} round {round}");
        }
    }
}

#[test]
fn scratch_reused_across_plans_matches_fresh() {
    // stale-capacity / stale-shape hazard: interleave all five models
    // (different programs, frame counts, buffer shapes) through ONE
    // scratch, three rounds with different inputs each round
    let arch = ArchConfig::default();
    let plans: Vec<ExecPlan> = MODELS
        .iter()
        .map(|m| ExecPlan::compile(&run_cfg(m)).unwrap())
        .collect();
    let mut scratch = ExecScratch::new();
    for round in 0..3u64 {
        for (plan, m) in plans.iter().zip(MODELS) {
            let x = plan.make_input(round);
            let fresh = plan.simulate(&arch, true, Some(&x), 0).unwrap();
            let reused = plan
                .simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                .unwrap();
            assert_eq!(fresh.cycles, reused.cycles, "{m} round {round}");
            assert_eq!(
                fresh.output.unwrap(),
                reused.output.unwrap(),
                "{m} round {round}"
            );
        }
    }
}

#[test]
fn warm_runs_do_not_grow_the_pool() {
    let arch = ArchConfig::default();
    for m in MODELS {
        let plan = ExecPlan::compile(&run_cfg(m)).unwrap();
        let x = plan.make_input(1);
        let mut scratch = ExecScratch::new();
        plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch)
            .unwrap();
        let after_cold = scratch.alloc_events();
        assert!(after_cold > 0, "{m}: the cold run must size the pool");
        for _ in 0..3 {
            plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                .unwrap();
        }
        assert_eq!(
            scratch.alloc_events(),
            after_cold,
            "{m}: warm runs must not grow the pool"
        );
    }
}

#[test]
fn warm_depth3_runs_do_not_grow_the_pool() {
    // the multi-layer chain buffer and all per-layer frames must pool:
    // a warm 3-layer request does zero allocation, same as depth 1
    let arch = ArchConfig::default();
    for m in MODELS {
        let mut run = run_cfg(m);
        run.layers = 3;
        let plan = ExecPlan::compile(&run).unwrap();
        let x = plan.make_input(1);
        let mut scratch = ExecScratch::new();
        plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch)
            .unwrap();
        let after_cold = scratch.alloc_events();
        assert!(after_cold > 0, "{m}: the cold run must size the pool");
        for _ in 0..3 {
            plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                .unwrap();
        }
        assert_eq!(
            scratch.alloc_events(),
            after_cold,
            "{m}: warm depth-3 runs must not grow the pool"
        );
    }
}

#[test]
fn one_scratch_serves_mixed_depths_bit_identically() {
    // depth-pooling hazard: interleave depth-1 and depth-3 plans of the
    // same model through ONE scratch and compare with fresh scratches
    let arch = ArchConfig::default();
    let mut scratch = ExecScratch::new();
    for m in ["gcn", "gat"] {
        let shallow = ExecPlan::compile(&run_cfg(m)).unwrap();
        let mut deep_run = run_cfg(m);
        deep_run.layers = 3;
        let deep = ExecPlan::compile(&deep_run).unwrap();
        for round in 0..2u64 {
            for plan in [&shallow, &deep] {
                let x = plan.make_input(round);
                let fresh = plan.simulate(&arch, true, Some(&x), 0).unwrap();
                let reused = plan
                    .simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                    .unwrap();
                assert_eq!(fresh.cycles, reused.cycles, "{m} round {round}");
                assert_eq!(fresh.output.unwrap(), reused.output.unwrap(), "{m} round {round}");
            }
        }
    }
}

#[test]
fn timing_only_runs_share_the_scratch_safely() {
    // the serving pool mixes functional and timing-only requests through
    // the same worker scratch; interleaving must not disturb either
    let arch = ArchConfig::default();
    let plan = ExecPlan::compile(&run_cfg("gat")).unwrap();
    let x = plan.make_input(2);
    let expect = plan
        .simulate(&arch, true, Some(&x), 0)
        .unwrap()
        .output
        .unwrap();
    let mut scratch = ExecScratch::new();
    for _ in 0..2 {
        let timing = plan
            .simulate_with(&arch, false, None, 0, &mut scratch)
            .unwrap();
        assert!(timing.output.is_none());
        let func = plan
            .simulate_with(&arch, true, Some(&x), 0, &mut scratch)
            .unwrap();
        assert_eq!(func.output.unwrap(), expect);
    }
}
