//! Cross-policy contracts for the kernel-variant layer (`KernelPolicy`):
//!
//! * every **f32** policy (scalar, SIMD, sparse-skip, and their
//!   combinations) produces bit-identical outputs on both execution
//!   paths — the SIMD lane-array kernels share the scalar kernels'
//!   per-output accumulation order, and the sparse skip only elides
//!   source rows that no edge ever gathers;
//! * sparse-skip **credits** timing and DRAM traffic for the elided
//!   8-row source blocks (Regular-mode tiling, where partial tile
//!   occupancy actually occurs) without perturbing outputs;
//! * **f16/bf16 storage** (f32 accumulate) stays within the documented
//!   error bound against the f32 run, engine and batched paths stay
//!   bit-identical to each other (they quantize at the same chain
//!   boundary), and quantization visibly bites (outputs differ from
//!   f32), on both the engine and `run_batch` paths.
//!
//! The error-bound derivation lives in DESIGN.md ("Kernel policies"):
//! quantizing weights and the incoming activation perturbs one GEMM
//! output by at most `(2u + u^2) * sum_k |x_k||w_kj|` (u = unit
//! roundoff: 2^-11 for f16, 2^-8 for bf16). At this fixture's scale the
//! per-layer term is over-approximated by `64*u*(1 + max|out_f32|)`,
//! so a depth-2 run uses `128*u*(1 + max|out_f32|)`.

use zipper::config::{ArchConfig, KernelPolicy, RunConfig, StorageDtype};
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::tiling::{Reorder, SKIP_BLOCK, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];

fn run_cfg(model: &str, layers: u32, mode: TilingMode, kernels: KernelPolicy) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        layers,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        serving: Default::default(),
        kernels,
        shards: 1,
        overlap: false,
    }
}

fn pol(simd: bool, sparse_skip: bool, dtype: StorageDtype) -> KernelPolicy {
    KernelPolicy { simd, sparse_skip, dtype }
}

/// Run one policy on both paths; the two must agree bit-exactly for
/// EVERY policy (shared dispatch core + shared quantization boundary),
/// so return just the engine output and its metrics.
fn run_both_paths(arch: &ArchConfig, run: &RunConfig, x: &[f32]) -> (Vec<f32>, u64, u64) {
    let plan = ExecPlan::compile(run).unwrap();
    let res = plan.simulate(arch, true, Some(x), 0).unwrap();
    let engine = res.output.unwrap();
    let mut scratch = BatchScratch::new();
    let batched = plan
        .execute_batch_with(&[x], 2, &mut scratch)
        .unwrap()
        .remove(0);
    assert_eq!(
        engine, batched,
        "{} {:?}: engine and batched outputs must be bit-identical",
        run.model, run.kernels
    );
    (engine, res.cycles, res.dram_read_bytes)
}

#[test]
fn all_f32_policies_bit_exact_across_models_and_paths() {
    let arch = ArchConfig::default();
    let f32_policies = [
        pol(false, false, StorageDtype::F32),
        pol(true, false, StorageDtype::F32),
        pol(false, true, StorageDtype::F32),
        pol(true, true, StorageDtype::F32),
    ];
    for m in MODELS {
        for depth in [1u32, 2] {
            let base = run_cfg(m, depth, TilingMode::Sparse, f32_policies[0]);
            let x = ExecPlan::compile(&base).unwrap().make_input(7);
            let (want, _, _) = run_both_paths(&arch, &base, &x);
            for p in &f32_policies[1..] {
                let run = run_cfg(m, depth, TilingMode::Sparse, *p);
                let (got, _, _) = run_both_paths(&arch, &run, &x);
                assert_eq!(got, want, "{m} depth={depth} {p:?}: f32 policies must agree");
            }
        }
    }
}

#[test]
fn sparse_skip_credits_timing_without_changing_outputs() {
    // Regular (grid) tiling loads every source vertex of the partition,
    // so tiles over a sparse graph have empty 8-row blocks — the case
    // the skip targets. Sparse-mode tiles are fully occupied by
    // construction and must be (and are, per the f32 test above) a
    // no-op for the skip.
    let arch = ArchConfig::default();
    let base = run_cfg("gcn", 1, TilingMode::Regular, pol(true, false, StorageDtype::F32));
    let plan = ExecPlan::compile(&base).unwrap();
    let partial = plan
        .tiling
        .partitions
        .iter()
        .flat_map(|p| &p.tiles)
        .filter(|t| !t.fully_occupied())
        .count();
    assert!(partial > 0, "fixture too weak: no partially occupied tile under Regular tiling");
    let some_credit = plan
        .tiling
        .partitions
        .iter()
        .flat_map(|p| &p.tiles)
        .any(|t| t.occupied_block_rows(SKIP_BLOCK) < t.src_vertices.len() as u32);
    assert!(some_credit, "fixture too weak: no tile has an empty skip block");

    let x = plan.make_input(7);
    let (want, base_cycles, base_dram) = run_both_paths(&arch, &base, &x);
    let skip = run_cfg("gcn", 1, TilingMode::Regular, pol(true, true, StorageDtype::F32));
    let (got, skip_cycles, skip_dram) = run_both_paths(&arch, &skip, &x);
    assert_eq!(got, want, "sparse-skip must never change functional outputs");
    assert!(
        skip_dram < base_dram,
        "skipped LD.SRC blocks must credit DRAM traffic ({skip_dram} !< {base_dram})"
    );
    assert!(
        skip_cycles <= base_cycles,
        "sparse-skip must never slow the simulated clock ({skip_cycles} > {base_cycles})"
    );
}

#[cfg(feature = "half")]
#[test]
fn reduced_precision_error_is_bounded_on_both_paths() {
    let arch = ArchConfig::default();
    for m in MODELS {
        for dtype in [StorageDtype::F16, StorageDtype::Bf16] {
            let depth = 2u32;
            let base = run_cfg(m, depth, TilingMode::Sparse, pol(true, false, StorageDtype::F32));
            let x = ExecPlan::compile(&base).unwrap().make_input(7);
            let (want, _, _) = run_both_paths(&arch, &base, &x);
            let run = run_cfg(m, depth, TilingMode::Sparse, pol(true, false, dtype));
            // run_both_paths already asserts engine == run_batch under
            // the reduced-precision policy (same quantization boundary)
            let (got, _, _) = run_both_paths(&arch, &run, &x);
            assert_ne!(got, want, "{m} {}: quantization never bit", dtype.name());
            let mag = want.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let tol = depth as f32 * 64.0 * dtype.unit_roundoff() * (1.0 + mag);
            let max_err = want
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err <= tol,
                "{m} {}: max err {max_err} over documented bound {tol}",
                dtype.name()
            );
        }
    }
}

#[cfg(feature = "half")]
#[test]
fn f16_is_tighter_than_bf16_tolerance() {
    // f16 carries 3 more mantissa bits than bf16 (u = 2^-11 vs 2^-8);
    // a correct implementation keeps the f16 run inside the *f16*
    // bound, which is 8x tighter than bf16's — a mixed-up dtype plumbing
    // (e.g. f16 flag applying bf16 rounding) trips this immediately.
    let arch = ArchConfig::default();
    let base = run_cfg("gcn", 2, TilingMode::Sparse, pol(true, false, StorageDtype::F32));
    let x = ExecPlan::compile(&base).unwrap().make_input(7);
    let (want, _, _) = run_both_paths(&arch, &base, &x);
    let mag = want.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let run = run_cfg("gcn", 2, TilingMode::Sparse, pol(true, false, StorageDtype::F16));
    let (got, _, _) = run_both_paths(&arch, &run, &x);
    let max_err = want
        .iter()
        .zip(&got)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let f16_tol = 2.0 * 64.0 * StorageDtype::F16.unit_roundoff() * (1.0 + mag);
    assert!(max_err <= f16_tol, "f16 run spilled past the f16-specific bound: {max_err}");
}
