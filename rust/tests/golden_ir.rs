//! Golden IR snapshots: the disassembled SDE programs of every model at
//! both optimization tiers (`e2v` and `pipeline` = all passes) are
//! pinned as text files under `tests/golden/`. Any compiler or
//! optimizer change that rewrites the emitted IR shows up as a readable
//! text diff instead of a silent behavior change.
//!
//! Blessing:
//! * a MISSING snapshot is written automatically and the test passes
//!   with a notice (first run / new model);
//! * `GOLDEN_BLESS=1 cargo test --test golden_ir` rewrites every
//!   snapshot from the current compiler output;
//! * a MISMATCH fails the test and leaves the fresh output next to the
//!   snapshot as `<name>.actual` (CI uploads the directory on failure).

use std::fs;
use std::path::PathBuf;
use zipper::compiler::{compile, optimize_pipeline, OptLevel, PassSet};
use zipper::models::{ModelKind, ModelSpec};

const DEPTH: u32 = 2;
const FEAT: u32 = 8;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// Render one model × tier as stable snapshot text: a header plus every
/// stage's deterministic disassembly.
fn render(kind: ModelKind, passes: PassSet) -> String {
    let spec = ModelSpec::new(kind, FEAT, &[], FEAT, DEPTH).expect("spec");
    let opt = if passes.is_empty() {
        OptLevel::E2v
    } else {
        OptLevel::Pipeline(passes)
    };
    let mut programs: Vec<_> = (0..spec.depth())
        .map(|l| compile(&spec.build_layer(l), opt).expect("compile"))
        .collect();
    let mut out = format!(
        "; golden IR: model {} depth {DEPTH} feat {FEAT}x{FEAT} passes {passes}\n",
        kind.name()
    );
    if !passes.is_empty() {
        let rep = optimize_pipeline(&mut programs, passes);
        out.push_str(&format!(
            "; optimizer: {} -> {} instructions\n",
            rep.instructions_before,
            rep.instructions_after()
        ));
        for p in &rep.passes {
            out.push_str(&format!(
                "; pass {}: removed {} fused {} hoisted {} freed {}\n",
                p.pass, p.report.removed, p.report.fused, p.report.hoisted, p.report.freed
            ));
        }
    }
    for (l, p) in programs.iter().enumerate() {
        out.push_str(&format!("\n; ----- layer {l} -----\n"));
        out.push_str(&p.disassemble());
    }
    out
}

fn check_snapshot(name: &str, actual: &str) -> Result<(), String> {
    let dir = golden_dir();
    fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let path = dir.join(format!("{name}.sde"));
    let bless = std::env::var("GOLDEN_BLESS").map(|v| v == "1").unwrap_or(false);
    match fs::read_to_string(&path) {
        Ok(want) if !bless => {
            if want == actual {
                // stale .actual from a previous failing run is noise
                let _ = fs::remove_file(dir.join(format!("{name}.actual")));
                Ok(())
            } else {
                let actual_path = dir.join(format!("{name}.actual"));
                fs::write(&actual_path, actual)
                    .map_err(|e| format!("{}: {e}", actual_path.display()))?;
                let diff_line = want
                    .lines()
                    .zip(actual.lines())
                    .position(|(a, b)| a != b)
                    .map(|i| i + 1)
                    .unwrap_or(want.lines().count().min(actual.lines().count()) + 1);
                Err(format!(
                    "golden IR mismatch for {name} (first differing line {diff_line}).\n\
                     expected: {}\n  actual: {}\n\
                     If the IR change is intentional, re-bless with \
                     GOLDEN_BLESS=1 cargo test --test golden_ir",
                    path.display(),
                    actual_path.display()
                ))
            }
        }
        _ => {
            // missing or blessing: write the snapshot
            fs::write(&path, actual).map_err(|e| format!("{}: {e}", path.display()))?;
            println!("blessed golden snapshot {}", path.display());
            Ok(())
        }
    }
}

#[test]
fn golden_ir_snapshots_per_model_and_tier() {
    let mut failures = Vec::new();
    for kind in ModelKind::ALL {
        for (tier, passes) in [("e2v", PassSet::none()), ("pipeline", PassSet::all())] {
            let name = format!("{}_{tier}", kind.name());
            let actual = render(kind, passes);
            if let Err(e) = check_snapshot(&name, &actual) {
                failures.push(e);
            }
        }
    }
    assert!(failures.is_empty(), "{}", failures.join("\n\n"));
}

/// `disassemble()` must be deterministic — byte-identical across
/// repeated compiles of the same layer — or the snapshots above would
/// flake.
#[test]
fn disassembly_is_deterministic() {
    for kind in ModelKind::ALL {
        let a = render(kind, PassSet::all());
        let b = render(kind, PassSet::all());
        assert_eq!(a, b, "{}: disassembly must be deterministic", kind.name());
    }
}
