//! Plan-layer integration tests: compile-once ExecPlans, the serving
//! plan cache, re-entrant simulation, and tiling invariants at the plan
//! boundary.

use std::sync::Arc;
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::{Coordinator, InferenceRequest};
use zipper::plan::{ExecPlan, PlanCache};
use zipper::sim::ExecScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

fn run_cfg(model: &str, seed: u64) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        layers: 1,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed,
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

#[test]
fn coordinator_plan_cache_hits_and_misses() {
    let mut c = Coordinator::new(ArchConfig::default(), 1);
    // 3 distinct operating points, each requested twice
    for i in 0..6u64 {
        let model = ["gcn", "gat", "sage"][(i % 3) as usize];
        c.submit(InferenceRequest { id: i, run: run_cfg(model, 3), input_seed: i });
    }
    let resp = c.drain();
    assert_eq!(resp.len(), 6);
    for r in &resp {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let stats = c.cache_stats();
    assert_eq!(stats.entries, 3, "one plan per operating point");
    // single worker ⇒ strictly sequential ⇒ exactly 3 misses, 3 hits
    assert_eq!((stats.misses, stats.hits), (3, 3));
    let warm = resp.iter().filter(|r| r.plan_cache_hit).count();
    assert_eq!(warm, 3);
    for r in resp.iter().filter(|r| r.plan_cache_hit) {
        assert_eq!(r.prepare_seconds, 0.0, "warm request must not pay compilation");
    }
}

#[test]
fn warm_pass_is_identical_and_all_hits() {
    let cache = Arc::new(PlanCache::new());
    let arch = ArchConfig::default();
    let serve = |cache: &Arc<PlanCache>| {
        let mut c = Coordinator::with_cache(arch, 2, Arc::clone(cache));
        for i in 0..4u64 {
            let model = ["gcn", "gat"][(i % 2) as usize];
            c.submit(InferenceRequest { id: i, run: run_cfg(model, 3), input_seed: i });
        }
        let mut resp = c.drain();
        resp.sort_by_key(|r| r.id);
        resp
    };
    let cold = serve(&cache);
    let warm = serve(&cache);
    assert!(warm.iter().all(|r| r.plan_cache_hit), "warm pass must be 100% cache hits");
    for (a, b) in cold.iter().zip(&warm) {
        assert!(a.error.is_none() && b.error.is_none());
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert_eq!(a.output_checksum, b.output_checksum);
    }
    assert_eq!(cache.stats().entries, 2);
}

#[test]
fn exec_plan_is_reentrant_across_threads() {
    // one immutable plan, many concurrent workers with private scratch:
    // every run must produce bit-identical output
    let plan = Arc::new(ExecPlan::compile(&run_cfg("gat", 5)).unwrap());
    let arch = ArchConfig::default();
    let x = plan.make_input(11);
    let reference = plan
        .simulate(&arch, true, Some(&x), 0)
        .unwrap()
        .output
        .unwrap();
    let mut handles = Vec::new();
    for _ in 0..4 {
        let plan = Arc::clone(&plan);
        let x = x.clone();
        handles.push(std::thread::spawn(move || {
            let mut scratch = ExecScratch::new();
            let mut outputs = Vec::new();
            for _ in 0..3 {
                let res = plan
                    .simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                    .unwrap();
                outputs.push(res.output.unwrap());
            }
            outputs
        }));
    }
    for h in handles {
        for out in h.join().unwrap() {
            assert_eq!(out, reference);
        }
    }
}

#[test]
fn plan_tiling_covers_every_edge_exactly_once() {
    for model in ["gcn", "rgcn"] {
        let plan = ExecPlan::compile(&run_cfg(model, 9)).unwrap();
        // rebuild the global edge multiset from the tiles
        let mut rebuilt: Vec<(u32, u32)> = Vec::new();
        for p in &plan.tiling.partitions {
            for t in &p.tiles {
                for &(ls, ld) in &t.edges {
                    rebuilt.push((t.src_vertices[ls as usize], p.dst_start + ld));
                }
            }
        }
        assert_eq!(rebuilt.len() as u64, plan.graph.num_edges(), "{model}");
        rebuilt.sort_unstable();
        // expected edges in *tiled* vertex ids (the tiling relabels)
        let mut expected: Vec<(u32, u32)> = Vec::new();
        for d in 0..plan.graph.num_vertices() {
            for &s in plan.graph.in_neighbors(d) {
                expected.push((plan.tiling.perm[s as usize], plan.tiling.perm[d as usize]));
            }
        }
        expected.sort_unstable();
        assert_eq!(rebuilt, expected, "{model}: every edge exactly once");
    }
}

#[test]
fn plan_permutation_round_trips() {
    let plan = ExecPlan::compile(&run_cfg("gcn", 13)).unwrap();
    let n = plan.dims.num_vertices;
    assert_eq!(plan.tiling.perm.len() as u32, n);
    assert_eq!(plan.tiling.inv_perm.len() as u32, n);
    for v in 0..n {
        assert_eq!(plan.tiling.inv_perm[plan.tiling.perm[v as usize] as usize], v);
        assert_eq!(plan.tiling.perm[plan.tiling.inv_perm[v as usize] as usize], v);
    }
    // derived dims agree with their sources
    assert_eq!(plan.dims.num_tiles, plan.tiling.num_tiles());
    assert_eq!(plan.dims.num_edges, plan.graph.num_edges());
    assert_eq!(plan.dims.input_len, n as usize * plan.feat_in as usize);
    assert_eq!(plan.dims.output_len, n as usize * plan.feat_out as usize);
}

#[test]
fn coordinator_survives_bad_requests_interleaved_with_good() {
    let mut c = Coordinator::new(ArchConfig::default(), 2);
    let mut bad = run_cfg("gcn", 3);
    bad.dataset = "NOPE".into();
    c.submit(InferenceRequest { id: 0, run: run_cfg("gcn", 3), input_seed: 0 });
    c.submit(InferenceRequest { id: 1, run: bad, input_seed: 1 });
    c.submit(InferenceRequest { id: 2, run: run_cfg("gcn", 3), input_seed: 2 });
    let mut resp = c.drain();
    assert_eq!(resp.len(), 3);
    resp.sort_by_key(|r| r.id);
    assert!(resp[0].error.is_none());
    assert!(resp[1].error.as_deref().unwrap().contains("unknown dataset"));
    assert!(resp[2].error.is_none());
}
