//! Integration tests: the full pipeline (dataset → tiling → compiler →
//! simulator → energy) across models, plus the three-layer PJRT
//! validation when artifacts are present.

use zipper::baselines::{self, DeviceModel};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::{Coordinator, InferenceRequest, Session};
use zipper::energy::EnergyModel;
use zipper::models::ModelKind;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

fn run_cfg(model: &str, dataset: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: dataset.into(),
        scale: 64,
        feat_in: 32,
        feat_out: 32,
        tiling: TilingConfig {
            dst_part: 512,
            src_part: 512,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: false,
        seed: 11,
        layers: 1,
        hidden: Vec::new(),
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

#[test]
fn every_model_on_every_table3_dataset() {
    let arch = ArchConfig::default();
    for m in ModelKind::ALL {
        for ds in ["AK", "AD", "CP"] {
            let mut cfg = run_cfg(m.name(), ds);
            cfg.scale = 128;
            let session = Session::prepare(&cfg)
                .unwrap_or_else(|e| panic!("{}/{ds}: {e}", m.name()));
            let res = session
                .simulate(&arch, false, None, 0)
                .unwrap_or_else(|e| panic!("{}/{ds}: {e}", m.name()));
            assert!(res.cycles > 0);
            // energy total must be positive and HBM-dominated-or-comparable
            let e = EnergyModel::default().evaluate(&res.counters, arch.freq_hz);
            assert!(e.total_j() > 0.0);
        }
    }
}

#[test]
fn zipper_beats_cpu_baseline_on_all_models() {
    // Fig 9's CPU-side ordering: ZIPPER simulated latency must be far
    // below the analytic DGL-CPU latency on the same (scaled) workload.
    let arch = ArchConfig::default();
    for m in ModelKind::ALL {
        let cfg = run_cfg(m.name(), "AD");
        let session = Session::prepare(&cfg).unwrap();
        let res = session.simulate(&arch, false, None, 0).unwrap();
        let zipper_s = res.seconds(&arch);
        let ops = baselines::whole_graph_ops(
            &m.build(),
            session.graph().num_vertices() as u64,
            session.graph().num_edges(),
            cfg.feat_in as u64,
            cfg.feat_out as u64,
        );
        let cpu = DeviceModel::cpu_dgl().run(&ops, 0);
        assert!(
            cpu.seconds > 5.0 * zipper_s,
            "{}: cpu {} vs zipper {}",
            m.name(),
            cpu.seconds,
            zipper_s
        );
    }
}

#[test]
fn sparse_tiling_reduces_dram_reads_end_to_end() {
    // Fig 11 mechanism check at integration level.
    let arch = ArchConfig::default();
    let mk = |mode, reorder| {
        let mut cfg = run_cfg("gcn", "CP");
        cfg.tiling.mode = mode;
        cfg.tiling.reorder = reorder;
        cfg.tiling.dst_part = 256;
        cfg.tiling.src_part = 256;
        let session = Session::prepare(&cfg).unwrap();
        session.simulate(&arch, false, None, 0).unwrap().dram_read_bytes
    };
    let regular = mk(TilingMode::Regular, Reorder::None);
    let sparse = mk(TilingMode::Sparse, Reorder::None);
    let sorted = mk(TilingMode::Sparse, Reorder::InDegree);
    assert!(sparse < regular, "sparse {sparse} !< regular {regular}");
    assert!(sorted <= sparse, "sorted {sorted} !<= sparse {sparse}");
}

#[test]
fn coordinator_parallel_serving_is_deterministic() {
    let mut c = Coordinator::new(ArchConfig::default(), 4);
    for i in 0..8 {
        let mut cfg = run_cfg("gat", "CR");
        cfg.scale = 8;
        cfg.functional = true;
        c.submit(InferenceRequest { id: i, run: cfg, input_seed: 5 });
    }
    let resp = c.drain();
    assert_eq!(resp.len(), 8);
    let sums: Vec<f64> = resp.iter().map(|r| r.output_checksum.unwrap()).collect();
    for s in &sums {
        assert!((s - sums[0]).abs() < 1e-6, "nondeterministic outputs: {sums:?}");
    }
}

// ---------------------------------------------------------------------------
// Property-based tests (in-tree deterministic RNG; proptest is not
// available offline). Each property runs over N seeded random cases.
// ---------------------------------------------------------------------------

mod properties {
    use super::*;
    use zipper::graph::generators;
    use zipper::tiling::tile;
    use zipper::util::Rng;

    /// Tiling conserves edges and keeps local indices in range for any
    /// (graph, partition-size, mode, reorder) combination.
    #[test]
    fn prop_tiling_conserves_edges() {
        let mut rng = Rng::new(0xF00D);
        for case in 0..40 {
            let v = 16 + rng.below(500) as u32;
            let e = 1 + rng.below(4_000);
            let g = generators::power_law(v, e, 0.6 + rng.next_f64(), 0.6 + rng.next_f64(), 0, case);
            let dst_part = 1 + rng.below(v as u64) as u32;
            let src_part = 1 + rng.below(v as u64) as u32;
            let mode = if rng.chance(0.5) { TilingMode::Sparse } else { TilingMode::Regular };
            let reorder = match rng.below(3) {
                0 => Reorder::None,
                1 => Reorder::InDegree,
                _ => Reorder::OutDegree,
            };
            let t = tile(&g, TilingConfig { dst_part, src_part, mode, reorder, threads: 1 });
            let total: u64 = t
                .partitions
                .iter()
                .flat_map(|p| p.tiles.iter())
                .map(|x| x.num_edges() as u64)
                .sum();
            assert_eq!(total, g.num_edges(), "case {case}: v={v} e={e}");
            for p in &t.partitions {
                for tl in &p.tiles {
                    for &(ls, ld) in &tl.edges {
                        assert!(ls < tl.num_src());
                        assert!(ld < p.num_dst());
                    }
                }
            }
        }
    }

    /// Functional simulation is invariant to tiling parameters, stream
    /// counts, and reordering: same graph + weights ⇒ same output.
    #[test]
    fn prop_functional_output_invariant_to_schedule() {
        let mut rng = Rng::new(0xBEEF);
        for case in 0..6 {
            let v = 64 + rng.below(150) as u32;
            let e = 200 + rng.below(800);
            let g = generators::power_law(v, e, 1.0, 1.0, 0, 100 + case);
            let mk = |dst_part: u32, src_part: u32, streams: u32, reorder| {
                let cfg = RunConfig {
                    model: "gcn".into(),
                    dataset: "unused".into(),
                    scale: 1,
                    feat_in: 16,
                    feat_out: 16,
                    tiling: TilingConfig {
                        dst_part,
                        src_part,
                        mode: TilingMode::Sparse,
                        reorder,
                        threads: 1,
                    },
                    e2v: true,
                    passes: Default::default(),
                    functional: true,
                    seed: 9,
                    layers: 1,
                    hidden: Vec::new(),
                    serving: Default::default(),
                    kernels: Default::default(),
                    shards: 1,
                    overlap: false,
                };
                let session =
                    Session::from_graph(ModelKind::Gcn, g.clone(), &cfg).unwrap();
                let x = session.make_input(33);
                let mut arch = ArchConfig::default();
                arch.s_streams = streams;
                arch.e_streams = streams;
                session.simulate(&arch, true, Some(&x), 0).unwrap().output.unwrap()
            };
            let a = mk(32, 32, 2, Reorder::None);
            let b = mk(64, 16, 4, Reorder::InDegree);
            let c = mk(v, v, 8, Reorder::OutDegree);
            for (i, ((x, y), z)) in a.iter().zip(&b).zip(&c).enumerate() {
                assert!(
                    (x - y).abs() < 1e-3 && (x - z).abs() < 1e-3,
                    "case {case} row {i}: {x} {y} {z}"
                );
            }
        }
    }

    /// E2V never changes functional results (any model, random graphs).
    #[test]
    fn prop_e2v_preserves_numerics() {
        let mut rng = Rng::new(0xCAFE);
        for case in 0..4 {
            let v = 50 + rng.below(100) as u32;
            let e = 100 + rng.below(500);
            for m in [ModelKind::Gat, ModelKind::Sage, ModelKind::Ggnn] {
                let g = generators::power_law(v, e, 1.0, 1.0, 0, 7 * case + 1);
                let mk = |e2v: bool| {
                    let cfg = RunConfig {
                        model: m.name().into(),
                        dataset: "unused".into(),
                        scale: 1,
                        feat_in: 8,
                        feat_out: 8,
                        tiling: TilingConfig {
                            dst_part: 32,
                            src_part: 32,
                            mode: TilingMode::Sparse,
                            reorder: Reorder::None,
                            threads: 1,
                        },
                        e2v,
                        passes: Default::default(),
                        functional: true,
                        seed: 3,
                        layers: 1,
                        hidden: Vec::new(),
                        serving: Default::default(),
                        kernels: Default::default(),
                        shards: 1,
                        overlap: false,
                    };
                    let s = Session::from_graph(m, g.clone(), &cfg).unwrap();
                    let x = s.make_input(21);
                    s.simulate(&ArchConfig::default(), true, Some(&x), 0)
                        .unwrap()
                        .output
                        .unwrap()
                };
                let naive = mk(false);
                let opt = mk(true);
                for (a, b) in naive.iter().zip(&opt) {
                    assert!((a - b).abs() < 1e-3, "{}: {a} vs {b}", m.name());
                }
            }
        }
    }

    /// Degree-sort reordering never increases total source loads on
    /// skewed graphs (the §5.3 claim).
    #[test]
    fn prop_reorder_never_hurts_much() {
        let mut rng = Rng::new(0xD1CE);
        for case in 0..20 {
            let v = 200 + rng.below(2_000) as u32;
            let e = (v as u64) * (2 + rng.below(8));
            let g = generators::power_law(v, e, 1.1, 1.1, 0, case + 500);
            let cfg = |reorder| TilingConfig {
                dst_part: 128,
                src_part: 128,
                mode: TilingMode::Sparse,
                reorder,
                threads: 1,
            };
            let plain = tile(&g, cfg(Reorder::None)).total_src_loads();
            let sorted = tile(&g, cfg(Reorder::InDegree)).total_src_loads();
            // allow 5% noise on small graphs, but no systematic regression
            assert!(
                (sorted as f64) < (plain as f64) * 1.05,
                "case {case}: sorted {sorted} vs plain {plain}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT three-layer validation (requires `make artifacts` and a build
// with a linked PJRT backend; skipped gracefully otherwise).
// ---------------------------------------------------------------------------

mod pjrt {
    use std::path::Path;
    use zipper::coordinator::validate;
    use zipper::models::ModelKind;
    use zipper::runtime::{Runtime, TileShape};

    /// The oracle runtime, when artifacts exist and a backend is linked.
    fn oracle() -> Option<Runtime> {
        let p = Path::new("artifacts");
        if !p.join("manifest.json").exists() {
            eprintln!("pjrt tests skipped: artifacts/manifest.json missing (run `make artifacts`)");
            return None;
        }
        match Runtime::new(p) {
            Ok(rt) if rt.available() => Some(rt),
            Ok(_) => {
                eprintln!("pjrt tests skipped: no PJRT backend linked into this build");
                None
            }
            Err(e) => {
                eprintln!("pjrt tests skipped: {e}");
                None
            }
        }
    }

    #[test]
    fn all_models_match_pjrt_oracle() {
        let Some(mut rt) = oracle() else { return };
        let shape = TileShape {
            num_src: 64,
            num_dst: 64,
            num_edges: 256,
            feat_in: 32,
            feat_out: 32,
        };
        for m in ModelKind::ALL {
            let r = validate::validate_model(&mut rt, m, &shape, 41)
                .unwrap_or_else(|e| panic!("{}: {e}", m.name()));
            assert!(
                r.pass,
                "{}: max err {} over {} rows",
                r.model, r.max_abs_err, r.rows_compared
            );
            assert!(r.mean_abs_err.is_finite());
        }
    }

    #[test]
    fn multi_layer_models_match_pjrt_oracle() {
        // the extended AOT oracle: 2- and 3-layer GCN/GAT/SAGE chains,
        // per-layer weights + hidden ReLU, vs the stacked ExecPlan
        let Some(mut rt) = oracle() else { return };
        let shape = TileShape {
            num_src: 64,
            num_dst: 64,
            num_edges: 256,
            feat_in: 32,
            feat_out: 32,
        };
        for m in [ModelKind::Gcn, ModelKind::Gat, ModelKind::Sage] {
            for depth in [2u32, 3] {
                let r = validate::validate_model_depth(&mut rt, m, &shape, 29, depth)
                    .unwrap_or_else(|e| panic!("{} depth {depth}: {e}", m.name()));
                assert_eq!(r.layers, depth);
                assert!(
                    r.pass,
                    "{} depth {depth}: max err {} over {} rows",
                    r.model, r.max_abs_err, r.rows_compared
                );
            }
        }
    }

    #[test]
    fn validation_is_seed_robust() {
        let Some(mut rt) = oracle() else { return };
        let shape = TileShape {
            num_src: 64,
            num_dst: 64,
            num_edges: 256,
            feat_in: 32,
            feat_out: 32,
        };
        for seed in [1u64, 2, 3] {
            let r = validate::validate_model(&mut rt, ModelKind::Gat, &shape, seed).unwrap();
            assert!(r.pass, "seed {seed}: {}", r.max_abs_err);
        }
    }
}
