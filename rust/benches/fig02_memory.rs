//! Fig 2 reproduction: total memory usage of GNNs vs PageRank vs DNNs on
//! whole-graph (classic DGL) execution, with the workspace breakdown and
//! the OOM behaviour on europe-osm.
//!
//! Paper's shape: GNNs (GAT, SAGE) need several× the memory of PageRank
//! on the same graph (workspace = per-edge intermediates), VGG16@256
//! sits in between, and both GNNs OOM on EO's 32 GB V100.
//!
//! Analytic model over *published* graph sizes — no scaling needed.

use zipper::baselines::{memory_footprint, refworkloads, DeviceModel};
use zipper::graph::datasets;
use zipper::metrics::Table;
use zipper::models;
use zipper::util::fmt_bytes;

fn main() {
    println!("== Fig 2: memory usage under whole-graph execution ==");
    println!("paper: SAGE/SL 16.3 GB vs PR/SL 3.7 GB vs VGG16@256 6.9 GB; GAT+SAGE OOM on EO\n");

    let gpu = DeviceModel::gpu_dgl();
    let cap = gpu.mem_cap_bytes.unwrap();
    let mut t = Table::new(&[
        "workload", "dataset", "graph", "weights", "features", "workspace", "total", "fits 32GB",
    ]);

    for ds in ["CP", "SL", "EO"] {
        let spec = datasets::by_id(ds).unwrap();
        let (v, e) = (spec.vertices, spec.edges);
        for (name, model) in [("GAT", models::gat()), ("SAGE", models::sage())] {
            let mb = memory_footprint(&model, v, e, 128, 128);
            t.row(&[
                name.into(),
                ds.into(),
                fmt_bytes(mb.graph_bytes),
                fmt_bytes(mb.weight_bytes),
                fmt_bytes(mb.feature_bytes),
                fmt_bytes(mb.workspace_bytes),
                fmt_bytes(mb.total()),
                if mb.total() > cap { "OOM".into() } else { "yes".into() },
            ]);
        }
        // PageRank: scalar ranks, no weights
        let pr_ws: f64 = refworkloads::pagerank(v, e).iter().map(|o| o.out_bytes).sum();
        let pr_total = v * 8 + e * 8 + v * 8 + pr_ws as u64;
        t.row(&[
            "PageRank".into(),
            ds.into(),
            fmt_bytes(e * 8 + v * 8),
            "0 B".into(),
            fmt_bytes(v * 8),
            fmt_bytes(pr_ws as u64),
            fmt_bytes(pr_total),
            if pr_total > cap { "OOM".into() } else { "yes".into() },
        ]);
    }
    // DNNs (dataset-independent)
    for (name, ops, weights) in [
        ("VGG16@256", refworkloads::vgg16(256), 528u64 * 1024 * 1024),
        ("ResNet50@256", refworkloads::resnet50(256), 98 * 1024 * 1024),
    ] {
        let ws: f64 = ops.iter().map(|o| o.out_bytes).sum();
        let total = weights + ws as u64;
        t.row(&[
            name.into(),
            "ImageNet".into(),
            "-".into(),
            fmt_bytes(weights),
            "-".into(),
            fmt_bytes(ws as u64),
            fmt_bytes(total),
            if total > cap { "OOM".into() } else { "yes".into() },
        ]);
    }
    print!("{}", t.render());

    // headline checks (the figure's qualitative claims)
    let sage_sl = memory_footprint(&models::sage(), 4_847_571, 43_369_619, 128, 128).total();
    let gat_eo = memory_footprint(&models::gat(), 50_912_018, 54_054_660, 128, 128).total();
    println!("\nSAGE/SL total: {} (paper: 16.3 GB measured)", fmt_bytes(sage_sl));
    println!(
        "GAT/EO total: {} -> OOM on 32 GB: {}",
        fmt_bytes(gat_eo),
        gat_eo > cap
    );
    assert!(gat_eo > cap, "GAT on EO must OOM (Fig 2)");
    assert!(
        sage_sl < cap,
        "SAGE on SL must fit (the paper measured it on the V100)"
    );
}
