//! Fig 9 reproduction: ZIPPER speedup over DGL-CPU and DGL-GPU across
//! 5 models × 6 datasets (single layer, F = 128).
//!
//! Paper headline: 93.6× over CPU and 1.56× over GPU on average, with
//! limited speedup / slowdown for GAT (DGL's fused softmax) and the GPU
//! OOM'ing on EO while ZIPPER runs it (tiling).
//!
//! Graphs are 1/1024-scale synthetics with matched degree shape
//! (DESIGN.md §5): speedup *ratios* survive scaling since ZIPPER and the
//! baselines process the same operator volumes.

use zipper::baselines::{memory_footprint, whole_graph_ops, DeviceModel};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::graph::datasets::TABLE3;
use zipper::metrics::Table;
use zipper::models::ModelKind;
use zipper::util::stats::geomean;

/// DGL's hand-fused softmax kernel for GAT (paper §8.2: "DGL has their
/// special operation support for the softmax attention") — the baseline
/// runs fewer/better-fused edge ops than our ISA program. Model that as
/// a fixed efficiency credit on the GAT baselines.
const DGL_GAT_SOFTMAX_CREDIT: f64 = 0.45;

fn main() {
    println!("== Fig 9: speedup over DGL-CPU / DGL-GPU (F=128, 1 layer) ==");
    println!("paper: avg 93.6x vs CPU, 1.56x vs GPU; GAT weakest; GPU OOM on EO\n");
    let arch = ArchConfig::default();
    let scale = 1024u64;
    let mut t = Table::new(&["model", "dataset", "ZIPPER ms", "CPU x", "GPU x"]);
    let mut cpu_all = Vec::new();
    let mut gpu_all = Vec::new();

    for model in ModelKind::ALL {
        for spec in &TABLE3 {
            let run = RunConfig {
                model: model.name().into(),
                dataset: spec.id.into(),
                scale,
                feat_in: 128,
                feat_out: 128,
                ..Default::default()
            };
            let session = Session::prepare(&run).expect("session");
            let res = session.simulate(&arch, false, None, 0).expect("simulate");
            let zipper_s = res.seconds(&arch);
            let (v, e) = (session.graph().num_vertices() as u64, session.graph().num_edges());
            let ops = whole_graph_ops(&model.build(), v, e, 128, 128);
            let mut cpu_s = DeviceModel::cpu_dgl().run(&ops, 0).seconds;
            let mb = memory_footprint(&model.build(), spec.vertices, spec.edges, 128, 128);
            let gpu_res = DeviceModel::gpu_dgl().run(&ops, 0);
            let mut gpu_s = gpu_res.seconds;
            if model == ModelKind::Gat {
                cpu_s *= DGL_GAT_SOFTMAX_CREDIT;
                gpu_s *= DGL_GAT_SOFTMAX_CREDIT;
            }
            // full-size footprint decides OOM (Fig 2 model)
            let gpu_oom = mb.total() > 32 * 1024 * 1024 * 1024;
            let cpu_x = cpu_s / zipper_s;
            let gpu_x = gpu_s / zipper_s;
            cpu_all.push(cpu_x);
            if !gpu_oom {
                gpu_all.push(gpu_x);
            }
            t.row(&[
                model.name().into(),
                spec.id.into(),
                format!("{:.3}", zipper_s * 1e3),
                format!("{cpu_x:.1}"),
                if gpu_oom { "OOM".into() } else { format!("{gpu_x:.2}") },
            ]);
        }
    }
    print!("{}", t.render());
    let cpu_avg = geomean(&cpu_all);
    let gpu_avg = geomean(&gpu_all);
    println!("\ngeomean speedup vs CPU: {cpu_avg:.1}x (paper 93.6x)");
    println!("geomean speedup vs GPU: {gpu_avg:.2}x (paper 1.56x)");
    assert!(cpu_avg > 10.0, "ZIPPER must dominate the CPU");
    assert!(gpu_avg > 1.0, "ZIPPER must edge out the GPU on average");
    assert!(
        gpu_avg < cpu_avg / 5.0,
        "GPU gap must be far smaller than CPU gap (shape of Fig 9)"
    );
}
