//! Multi-chip shard-count sweep (DESIGN.md §3.8–3.9): one RMAT graph,
//! one depth-2 GCN plan per shard count K ∈ {1, 2, 4, 8} — compiled
//! both serial and with operator-level overlap — cycle scaling vs the
//! K=1 baseline, the halo-exchange share of traffic and time, and how
//! much of the exchange the overlap schedule hides. Asserts the
//! acceptance bars on the full-size (2^20-vertex) graph: K=4 cycles
//! within 1.35× of linear scaling, monotone non-increasing cycles
//! across the whole K sweep, and overlap speedup > 1.0 at every K ≥ 2.
//! Overlap may never be slower than serial at any size. Smoke mode
//! shrinks the graph to CI size, drops K=8, and additionally proves the
//! sharded stitch is bit-exact against the unsharded functional output
//! on both execution paths, overlap on AND off. Emits
//! `BENCH_shard.json`.
//!
//! ```bash
//! cargo bench --bench perf_shard            # RMAT 2^20, ~8M edges
//! cargo bench --bench perf_shard -- --smoke # tiny CI-sized run
//! ```

use std::collections::BTreeMap;
use std::time::Instant;
use zipper::config::{ArchConfig, RunConfig};
use zipper::graph::generators;
use zipper::metrics::Table;
use zipper::models::ModelKind;
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};
use zipper::util::json::Json;

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn run_cfg(scale_log2: u32, shards: u32, overlap: bool) -> RunConfig {
    RunConfig {
        model: "gcn".into(),
        dataset: format!("rmat{scale_log2}"),
        scale: 1,
        feat_in: 16,
        feat_out: 16,
        layers: 2,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 256,
            src_part: 256,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: false,
        seed: 7,
        serving: Default::default(),
        kernels: Default::default(),
        shards,
        overlap,
    }
}

fn main() {
    let (scale_log2, num_edges, ks): (u32, u64, &[u32]) =
        if smoke() { (10, 4_096, &[1, 2, 4]) } else { (20, 8_388_608, &[1, 2, 4, 8]) };
    let arch = ArchConfig::default();
    let graph = generators::rmat(scale_log2, num_edges, 7);
    println!(
        "== shard sweep: RMAT 2^{scale_log2} (|V|={} |E|={}), depth-2 GCN ==",
        graph.num_vertices(),
        graph.num_edges()
    );

    let mut table = Table::new(&[
        "K", "cycles", "speedup", "ovl cycles", "ovl speedup", "hidden %", "cut %",
        "halo vertices", "halo traffic", "halo share %", "compile s",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base_cycles = 0u64;
    let mut prev_cycles = u64::MAX;

    for &k in ks {
        let t0 = Instant::now();
        let plan = ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_cfg(scale_log2, k, false))
            .expect("plan compiles");
        let compile_s = t0.elapsed().as_secs_f64();
        let res = plan.simulate(&arch, false, None, 0).expect("timing run");
        if k == 1 {
            base_cycles = res.cycles;
        }
        let speedup = base_cycles as f64 / res.cycles as f64;
        let halo_share = res.halo.cycles as f64 / res.cycles as f64;
        let cut = plan
            .sharding
            .as_ref()
            .map(|s| s.partition.cut_fraction())
            .unwrap_or(0.0);

        // the overlap variant of the same cut (K ≥ 2 only): exchange
        // cycles hidden behind halo-independent tiles
        let ovl = (k >= 2).then(|| {
            let plan =
                ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_cfg(scale_log2, k, true))
                    .expect("overlap plan compiles");
            plan.simulate(&arch, false, None, 0).expect("overlap timing run")
        });
        let (ovl_cycles, ovl_speedup, hidden_share) = match &ovl {
            Some(o) => {
                assert!(
                    o.cycles <= res.cycles,
                    "K={k}: overlap ({}) must never be slower than serial ({})",
                    o.cycles,
                    res.cycles
                );
                assert_eq!(
                    o.halo.hidden_cycles + o.halo.exposed_cycles,
                    o.halo.cycles,
                    "K={k}: hidden + exposed must equal the total exchange cost"
                );
                let share = if o.halo.cycles > 0 {
                    o.halo.hidden_cycles as f64 / o.halo.cycles as f64
                } else {
                    0.0
                };
                (Some(o.cycles), Some(res.cycles as f64 / o.cycles as f64), Some(share))
            }
            None => (None, None, None),
        };
        table.row(&[
            k.to_string(),
            res.cycles.to_string(),
            format!("{speedup:.2}x"),
            ovl_cycles.map_or("-".into(), |c| c.to_string()),
            ovl_speedup.map_or("-".into(), |s| format!("{s:.3}x")),
            hidden_share.map_or("-".into(), |h| format!("{:.1}", 100.0 * h)),
            format!("{:.1}", 100.0 * cut),
            res.halo.vertices.to_string(),
            zipper::util::fmt_bytes(res.halo.bytes),
            format!("{:.1}", 100.0 * halo_share),
            format!("{compile_s:.2}"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("shards".to_string(), num(k as f64));
        row.insert("cycles".to_string(), num(res.cycles as f64));
        row.insert("speedup".to_string(), num(speedup));
        row.insert("cut_fraction".to_string(), num(cut));
        row.insert("halo_vertices".to_string(), num(res.halo.vertices as f64));
        row.insert("halo_bytes".to_string(), num(res.halo.bytes as f64));
        row.insert("halo_cycle_share".to_string(), num(halo_share));
        row.insert("overlap_cycles".to_string(), ovl_cycles.map_or(Json::Null, |c| num(c as f64)));
        row.insert("overlap_speedup".to_string(), ovl_speedup.map_or(Json::Null, num));
        row.insert("hidden_cycle_share".to_string(), hidden_share.map_or(Json::Null, num));
        row.insert("compile_seconds".to_string(), num(compile_s));
        rows.push(Json::Obj(row));

        if !smoke() {
            // acceptance: K=4 within 1.35x of linear on the full graph
            if k == 4 {
                let linear = base_cycles as f64 / 4.0;
                assert!(
                    (res.cycles as f64) <= 1.35 * linear,
                    "K=4 cycles {} exceed 1.35x linear ({:.0})",
                    res.cycles,
                    linear
                );
            }
            // acceptance: adding chips never costs cycles at this size
            assert!(
                res.cycles <= prev_cycles,
                "K={k}: cycles {} regressed over the previous shard count ({prev_cycles})",
                res.cycles
            );
            // acceptance: the overlap schedule hides real exchange time
            if let Some(s) = ovl_speedup {
                assert!(s > 1.0, "K={k}: overlap speedup {s:.4} must exceed 1.0");
            }
        }
        prev_cycles = res.cycles;
    }

    if smoke() {
        // bit-exact stitch: K in {2, 4}, overlap on AND off, must
        // reproduce the unsharded functional output on BOTH paths
        let mut frun = run_cfg(scale_log2, 1, false);
        frun.functional = true;
        let base = ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &frun)
            .expect("baseline compiles");
        let x = base.make_input(11);
        let want = base
            .simulate(&arch, true, Some(&x), 0)
            .expect("baseline run")
            .output
            .expect("baseline output");
        for k in [2u32, 4] {
            for overlap in [false, true] {
                let mut srun = run_cfg(scale_log2, k, overlap);
                srun.functional = true;
                let plan = ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &srun)
                    .expect("sharded plan compiles");
                let got = plan
                    .simulate(&arch, true, Some(&x), 0)
                    .expect("sharded run")
                    .output
                    .expect("sharded output");
                assert_eq!(
                    got, want,
                    "K={k} overlap={overlap}: sharded engine stitch must be bit-exact"
                );
                let mut scratch = BatchScratch::new();
                let outs = plan
                    .execute_batch_with(&[&x], 2, &mut scratch)
                    .expect("sharded batched run");
                assert_eq!(
                    outs[0], want,
                    "K={k} overlap={overlap}: sharded batched stitch must be bit-exact"
                );
            }
        }
        println!("smoke: sharded stitch bit-exact for K in {{2, 4}} x overlap on/off, both paths");
    }

    print!("{}", table.render());

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf_shard".to_string()));
    root.insert("graph".to_string(), Json::Str(format!("rmat{scale_log2}")));
    root.insert("num_vertices".to_string(), num((1u64 << scale_log2) as f64));
    root.insert("num_edges".to_string(), num(graph.num_edges() as f64));
    root.insert("model".to_string(), Json::Str("gcn".to_string()));
    root.insert("depth".to_string(), num(2.0));
    root.insert("sweep".to_string(), Json::Arr(rows));
    let path = "BENCH_shard.json";
    std::fs::write(path, Json::Obj(root).to_string_pretty()).expect("write BENCH_shard.json");
    println!("wrote {path}");
}
