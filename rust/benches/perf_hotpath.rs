//! Perf microbenches for the L3 hot paths.
//!
//! Measures, with wall-clock timing over repeated runs:
//!   * simulator engine throughput (simulated instructions / host second)
//!   * functional-mode throughput (instructions/s with tensor execution)
//!   * tiling construction throughput (edges / second), serial + threaded
//!   * the in-place tensor kernels (GEMM / BMM / GEMV / SCTR / GTHR) at
//!     the five models' operating-point dims (128 features, 2048-vertex
//!     source tiles — paper Table 4). The GEMM rows measure BOTH the
//!     scalar blocked kernel and the SIMD lane-array variant against the
//!     pre-blocking reference kernel kept verbatim below; scalar and
//!     SIMD must be bit-exact, and full (non-`--reps`) runs assert the
//!     SIMD variant holds >= 2x over the reference at 128 features
//!   * a kernel-policy sweep (scalar / simd / sparse-skip, plus f16 and
//!     bf16 when built with the `half` feature) over a depth-2 plan on
//!     BOTH execution paths: engine and batched outputs must be
//!     bit-identical under every policy, f32 policies bit-exact with the
//!     scalar baseline, reduced precision within the documented bound
//!   * warm-path allocation counts: after the first (cold) request on a
//!     reused `ExecScratch`, further requests must grow the pool by 0
//!
//! Emits `BENCH_hotpath.json`. Flags: `--scale N` overrides the dataset
//! scale divisor (larger = smaller graphs; CI smoke uses 65536),
//! `--reps N` overrides every rep count.
//!
//! Run before/after each optimization; keep if >5% better.

use std::collections::BTreeMap;
use std::time::Instant;
use zipper::config::{ArchConfig, KernelPolicy, RunConfig, StorageDtype};
use zipper::coordinator::Session;
use zipper::graph::generators;
use zipper::isa::{Reduce, SctrDir};
use zipper::metrics::Table;
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::sim::tensor::{self, Tensor};
use zipper::sim::ExecScratch;
use zipper::tiling::{tile, Reorder, TilingConfig, TilingMode};
use zipper::util::json::Json;
use zipper::util::Rng;

fn time<R>(mut f: impl FnMut() -> R, reps: u32) -> (f64, R) {
    // warmup
    let mut out = f();
    let t0 = Instant::now();
    for _ in 0..reps {
        out = f();
    }
    (t0.elapsed().as_secs_f64() / reps as f64, out)
}

fn arg(name: &str) -> Option<u64> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
}

/// The pre-blocking GEMM kernel (row-at-a-time ikj with a 4-way k
/// unroll), kept verbatim as the speedup baseline for the microbench.
fn matmul_reference(x: &Tensor, w: &[f32], k: u32, n: u32, out: &mut Tensor) {
    assert_eq!(x.cols, k, "GEMM inner dim");
    assert_eq!((out.rows, out.cols), (x.rows, n), "GEMM out shape");
    out.data.fill(0.0);
    let (k, n) = (k as usize, n as usize);
    for r in 0..x.rows as usize {
        let xrow = &x.data[r * k..(r + 1) * k];
        let orow = &mut out.data[r * n..(r + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let (x0, x1, x2, x3) = (xrow[kk], xrow[kk + 1], xrow[kk + 2], xrow[kk + 3]);
            let w0 = &w[kk * n..kk * n + n];
            let w1 = &w[(kk + 1) * n..(kk + 1) * n + n];
            let w2 = &w[(kk + 2) * n..(kk + 2) * n + n];
            let w3 = &w[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                orow[j] += x0 * w0[j] + x1 * w1[j] + x2 * w2[j] + x3 * w3[j];
            }
            kk += 4;
        }
        while kk < k {
            let xv = xrow[kk];
            let wrow = &w[kk * n..kk * n + n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
            kk += 1;
        }
    }
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn small_run(model: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        layers: 1,
        hidden: Vec::new(),
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    }
}

fn main() {
    let arch = ArchConfig::default();
    let reps_override = arg("--reps").map(|r| r as u32);
    let reps = |default: u32| reps_override.unwrap_or(default);
    let mut t = Table::new(&["bench", "time/iter", "throughput"]);
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf_hotpath".to_string()));

    // -- simulator timing-only throughput ---------------------------------
    let run = RunConfig {
        model: "gat".into(),
        dataset: "CP".into(),
        scale: arg("--scale").unwrap_or(512),
        feat_in: 128,
        feat_out: 128,
        ..Default::default()
    };
    let session = Session::prepare(&run).expect("session");
    let (dt, res) = time(|| session.simulate(&arch, false, None, 0).unwrap(), reps(5));
    t.row(&[
        format!("sim engine (GAT/CP 1/{}, timing)", run.scale),
        format!("{:.1} ms", dt * 1e3),
        format!("{:.2} M instr/s", res.instructions as f64 / dt / 1e6),
    ]);
    root.insert("sim_instr_per_s".to_string(), num(res.instructions as f64 / dt));

    // -- functional simulation (reused scratch = serving hot path) ---------
    let mut frun = run.clone();
    frun.scale = arg("--scale").unwrap_or(2048);
    frun.feat_in = 64;
    frun.feat_out = 64;
    let fsession = Session::prepare(&frun).expect("session");
    let x = fsession.make_input(1);
    let mut scratch = ExecScratch::new();
    let (dt, res) = time(
        || {
            fsession
                .simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                .unwrap()
        },
        reps(3),
    );
    t.row(&[
        format!("sim engine (GAT/CP 1/{}, functional)", frun.scale),
        format!("{:.1} ms", dt * 1e3),
        format!("{:.2} M instr/s", res.instructions as f64 / dt / 1e6),
    ]);
    root.insert("func_instr_per_s".to_string(), num(res.instructions as f64 / dt));

    // -- tiling construction, serial vs threaded ---------------------------
    let tile_v = 40_000u32 / (arg("--scale").map_or(1, |s| (s / 512).max(1)) as u32);
    let g = generators::power_law(tile_v.max(1_000), tile_v as u64 * 10, 1.1, 1.1, 0, 3);
    let mut tiling_rows: Vec<Json> = Vec::new();
    let mut serial_dt = 0.0;
    for threads in [1u32, 4] {
        let cfg = TilingConfig { threads, ..TilingConfig::default() };
        let (dt, tl) = time(|| tile(&g, cfg), reps(5));
        if threads == 1 {
            serial_dt = dt;
        }
        t.row(&[
            format!("tiling ({}k V, sparse+reorder, {threads} thr)", g.num_vertices() / 1000),
            format!("{:.1} ms", dt * 1e3),
            format!(
                "{:.1} M edges/s ({:.2}x)",
                tl.num_edges as f64 / dt / 1e6,
                serial_dt / dt
            ),
        ]);
        let mut row = BTreeMap::new();
        row.insert("threads".to_string(), num(threads as f64));
        row.insert("seconds".to_string(), num(dt));
        row.insert("edges_per_s".to_string(), num(tl.num_edges as f64 / dt));
        tiling_rows.push(Json::Obj(row));
    }
    root.insert("tiling".to_string(), Json::Arr(tiling_rows));

    // -- dense kernels at the five models' operating-point dims ------------
    // 128-feature layers over a 2048-vertex source tile (Table 4 defaults);
    // R-GCN's dense op is the per-edge typed BMM over a tile's edge list.
    let gemm_dims: [(&str, u32, u32, u32, bool); 4] = [
        ("gcn", 2048, 128, 128, false),
        ("gat", 2048, 128, 128, false),
        ("sage", 2048, 128, 128, false),
        ("ggnn", 2048, 128, 128, true), // GRU gates accumulate into dst
    ];
    let mut rng = Rng::new(7);
    let mut gemm_rows: Vec<Json> = Vec::new();
    for (model, m, k, n, accumulate) in gemm_dims {
        let x = Tensor::from_rows(
            m,
            k,
            (0..m as usize * k as usize).map(|_| rng.next_f32_sym()).collect(),
        );
        let w: Vec<f32> = (0..k as usize * n as usize).map(|_| rng.next_f32_sym()).collect();
        let mut ref_out = Tensor::zeros(m, n);
        let (ref_dt, _) = time(
            || {
                matmul_reference(&x, &w, k, n, &mut ref_out);
                ref_out.data[0]
            },
            reps(20),
        );
        let mut scalar_out = Tensor::zeros(m, n);
        let (scalar_dt, _) = time(
            || {
                if accumulate {
                    scalar_out.data.fill(0.0);
                }
                tensor::matmul_with(&x, &w, k, n, &mut scalar_out, accumulate, false).unwrap();
                scalar_out.data[0]
            },
            reps(20),
        );
        let mut simd_out = Tensor::zeros(m, n);
        let (simd_dt, _) = time(
            || {
                if accumulate {
                    simd_out.data.fill(0.0);
                }
                tensor::matmul_with(&x, &w, k, n, &mut simd_out, accumulate, true).unwrap();
                simd_out.data[0]
            },
            reps(20),
        );
        // differential checks: the SIMD variant is bit-exact with the
        // scalar blocked kernel (same per-output accumulation order),
        // and both stay within reassociation distance of the reference
        matmul_reference(&x, &w, k, n, &mut ref_out);
        scalar_out.data.fill(0.0);
        tensor::matmul_with(&x, &w, k, n, &mut scalar_out, accumulate, false).unwrap();
        simd_out.data.fill(0.0);
        tensor::matmul_with(&x, &w, k, n, &mut simd_out, accumulate, true).unwrap();
        assert_eq!(
            scalar_out.data, simd_out.data,
            "{model}: SIMD GEMM must be bit-exact with the scalar kernel"
        );
        let max_err = ref_out
            .data
            .iter()
            .zip(&scalar_out.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "{model}: blocked GEMM diverges ({max_err})");
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let speedup = ref_dt / simd_dt;
        if reps_override.is_none() {
            // acceptance floor, full runs only (smoke reps are too noisy
            // for wall-clock asserts): SIMD GEMM holds >= 2x over the
            // scalar reference kernel at the 128-feature operating point
            assert!(
                speedup >= 2.0,
                "{model}: SIMD GEMM {speedup:.2}x < 2x over the scalar reference"
            );
        }
        t.row(&[
            format!("GEMM {model} {m}x{k}x{n}{}", if accumulate { " +acc" } else { "" }),
            format!("{:.1} us", simd_dt * 1e6),
            format!(
                "simd {:.2} GFLOP/s ({:.2}x ref, {:.2}x scalar)",
                flops / simd_dt / 1e9,
                speedup,
                scalar_dt / simd_dt
            ),
        ]);
        let mut row = BTreeMap::new();
        row.insert("model".to_string(), Json::Str(model.to_string()));
        row.insert("m".to_string(), num(m as f64));
        row.insert("k".to_string(), num(k as f64));
        row.insert("n".to_string(), num(n as f64));
        row.insert("ref_gflops".to_string(), num(flops / ref_dt / 1e9));
        row.insert("scalar_gflops".to_string(), num(flops / scalar_dt / 1e9));
        row.insert("simd_gflops".to_string(), num(flops / simd_dt / 1e9));
        row.insert("simd_speedup_vs_ref".to_string(), num(speedup));
        gemm_rows.push(Json::Obj(row));
    }
    root.insert("gemm".to_string(), Json::Arr(gemm_rows));

    // R-GCN: per-edge typed BMM over a tile's edge list (3 relations)
    {
        let (edges, k, n) = (8192u32, 128u32, 128u32);
        let x = Tensor::from_rows(
            edges,
            k,
            (0..edges as usize * k as usize).map(|_| rng.next_f32_sym()).collect(),
        );
        let wset: Vec<f32> =
            (0..3 * k as usize * n as usize).map(|_| rng.next_f32_sym()).collect();
        let etypes: Vec<u8> = (0..edges as usize).map(|_| (rng.below(3)) as u8).collect();
        let mut out = Tensor::default();
        let (dt, _) = time(
            || {
                tensor::bmm_by_type(&x, &wset, k, n, Some(&etypes), &mut out).unwrap();
                out.data[0]
            },
            reps(5),
        );
        let flops = 2.0 * edges as f64 * k as f64 * n as f64;
        t.row(&[
            format!("BMM rgcn {edges}x{k}x{n} (3 rel)"),
            format!("{:.1} us", dt * 1e6),
            format!("{:.2} GFLOP/s", flops / dt / 1e9),
        ]);
        root.insert("bmm_gflops".to_string(), num(flops / dt / 1e9));
    }

    // GAT: attention GEMV over a tile's edge scores
    {
        let (m, k) = (8192u32, 128u32);
        let x = Tensor::from_rows(
            m,
            k,
            (0..m as usize * k as usize).map(|_| rng.next_f32_sym()).collect(),
        );
        let w: Vec<f32> = (0..k as usize).map(|_| rng.next_f32_sym()).collect();
        let mut out = Tensor::default();
        let (dt, _) = time(
            || {
                tensor::gemv(&x, &w, &mut out).unwrap();
                out.data[0]
            },
            reps(50),
        );
        t.row(&[
            format!("GEMV gat {m}x{k}"),
            format!("{:.1} us", dt * 1e6),
            format!("{:.2} GFLOP/s", 2.0 * m as f64 * k as f64 / dt / 1e9),
        ]);
        root.insert("gemv_gflops".to_string(), num(2.0 * m as f64 * k as f64 / dt / 1e9));
    }

    // -- GOP kernels: SCTR / GTHR over a synthetic tile --------------------
    {
        let (verts, edges_n, cols) = (2048u32, 16384usize, 128u32);
        let edges: Vec<(u32, u32)> = (0..edges_n)
            .map(|_| (rng.below(verts as u64) as u32, rng.below(verts as u64) as u32))
            .collect();
        let v = Tensor::filled(verts, cols, 1.25);
        let mut e = Tensor::default();
        let (dt, _) = time(
            || {
                tensor::scatter_rows(&v, &edges, SctrDir::OutEdge, cols, &mut e).unwrap();
                e.data[0]
            },
            reps(20),
        );
        let elems = edges_n as f64 * cols as f64;
        t.row(&[
            format!("SCTR {edges_n} edges x {cols}"),
            format!("{:.1} us", dt * 1e6),
            format!("{:.0} M elem/s", elems / dt / 1e6),
        ]);
        root.insert("sctr_elems_per_s".to_string(), num(elems / dt));
        let mut acc = Tensor::zeros(verts, cols);
        let (dt, _) = time(
            || {
                tensor::gather_rows(Reduce::Sum, &e, &edges, &mut acc).unwrap();
                acc.data[0]
            },
            reps(20),
        );
        t.row(&[
            format!("GTHR {edges_n} edges x {cols} (sum)"),
            format!("{:.1} us", dt * 1e6),
            format!("{:.0} M elem/s", elems / dt / 1e6),
        ]);
        root.insert("gthr_elems_per_s".to_string(), num(elems / dt));
    }

    // -- kernel-policy sweep: engine + batched path under every policy -----
    // A depth-2 GAT plan (so the inter-layer chain quantization actually
    // bites) executed on BOTH paths per policy. Contracts checked here
    // and re-checked at scale in tests/kernel_policies.rs:
    //   * engine and batched outputs bit-identical under every policy
    //   * every f32 policy bit-exact with the scalar baseline
    //   * f16/bf16 within the documented bound (DESIGN.md "Kernel
    //     policies"): 128*u*(1 + max|f32 out|) over-approximates the
    //     per-layer (2u+u^2)*sum|x||w| term at this fixture's scale
    {
        let mkpol = |simd, sparse_skip, dtype| KernelPolicy { simd, sparse_skip, dtype };
        let mut policies = vec![
            ("scalar", mkpol(false, false, StorageDtype::F32)),
            ("simd", mkpol(true, false, StorageDtype::F32)),
            ("sparse-skip", mkpol(true, true, StorageDtype::F32)),
        ];
        if cfg!(feature = "half") {
            policies.push(("f16", mkpol(true, false, StorageDtype::F16)));
            policies.push(("bf16", mkpol(true, false, StorageDtype::Bf16)));
        }
        let mut base_run = small_run("gat");
        base_run.layers = 2;
        base_run.kernels = mkpol(false, false, StorageDtype::F32);
        let base_plan = ExecPlan::compile(&base_run).expect("plan");
        let x = base_plan.make_input(5);
        let baseline = base_plan.simulate(&arch, true, Some(&x), 0).unwrap().output.unwrap();
        let base_mag = baseline.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let mut sweep = BTreeMap::new();
        for (name, pol) in policies {
            let mut run = base_run.clone();
            run.kernels = pol;
            let plan = ExecPlan::compile(&run).expect("plan");
            let res = plan.simulate(&arch, true, Some(&x), 0).unwrap();
            let engine = res.output.unwrap();
            let mut scratch = BatchScratch::new();
            let batched = plan
                .execute_batch_with(&[x.as_slice()], 2, &mut scratch)
                .unwrap()
                .remove(0);
            assert_eq!(engine, batched, "{name}: engine and batched paths diverge");
            let max_err = baseline
                .iter()
                .zip(&engine)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if pol.dtype == StorageDtype::F32 {
                assert_eq!(engine, baseline, "{name}: f32 policies must be bit-exact");
            } else {
                let tol = 128.0 * pol.dtype.unit_roundoff() * (1.0 + base_mag);
                assert!(max_err <= tol, "{name}: err {max_err} over bound {tol}");
            }
            t.row(&[
                format!("policy {name} (gat depth-2, engine+batch)"),
                format!("cycles {}", res.cycles),
                format!("max err {max_err:.2e}"),
            ]);
            let mut row = BTreeMap::new();
            row.insert("cycles".to_string(), num(res.cycles as f64));
            row.insert("dram_read_bytes".to_string(), num(res.dram_read_bytes as f64));
            row.insert("max_abs_err_vs_f32".to_string(), num(max_err as f64));
            sweep.insert(name.to_string(), Json::Obj(row));
        }
        root.insert("policy_sweep".to_string(), Json::Obj(sweep));
    }

    // -- warm-path allocation counter: must be 0 after the cold run --------
    let mut warm = BTreeMap::new();
    for model in ["gcn", "gat", "sage", "ggnn", "rgcn"] {
        let plan = ExecPlan::compile(&small_run(model)).expect("plan");
        let x = plan.make_input(1);
        let mut scratch = ExecScratch::new();
        plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch)
            .expect("cold run");
        let cold = scratch.alloc_events();
        for _ in 0..3 {
            plan.simulate_with(&arch, true, Some(&x), 0, &mut scratch)
                .expect("warm run");
        }
        let warm_delta = scratch.alloc_events() - cold;
        assert_eq!(warm_delta, 0, "{model}: warm requests must not grow the pool");
        t.row(&[
            format!("warm allocs ({model}, 3 reqs)"),
            format!("cold {cold}"),
            format!("warm +{warm_delta}"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("cold".to_string(), num(cold as f64));
        row.insert("warm_delta".to_string(), num(warm_delta as f64));
        warm.insert(model.to_string(), Json::Obj(row));
    }
    root.insert("warm_allocs".to_string(), Json::Obj(warm));

    print!("{}", t.render());
    let path = "BENCH_hotpath.json";
    std::fs::write(path, Json::Obj(root).to_string_pretty()).expect("write BENCH_hotpath.json");
    println!("wrote {path}");
}
