//! Perf microbenches for the L3 hot paths.
//!
//! Measures, with wall-clock timing over repeated runs:
//!   * simulator engine throughput (simulated instructions / host second)
//!   * functional-mode throughput (instructions/s with tensor execution)
//!   * tiling construction throughput (edges / second)
//!   * functional GEMM kernel (MFLOP/s of the tensor executor)
//!
//! Run before/after each optimization; keep if >5% better.

use std::time::Instant;
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::graph::generators;
use zipper::metrics::Table;
use zipper::sim::tensor::{matmul, Tensor};
use zipper::tiling::{tile, TilingConfig};

fn time<R>(mut f: impl FnMut() -> R, reps: u32) -> (f64, R) {
    // warmup
    let mut out = f();
    let t0 = Instant::now();
    for _ in 0..reps {
        out = f();
    }
    (t0.elapsed().as_secs_f64() / reps as f64, out)
}

fn main() {
    let arch = ArchConfig::default();
    let mut t = Table::new(&["bench", "time/iter", "throughput"]);

    // -- simulator timing-only throughput ---------------------------------
    let run = RunConfig {
        model: "gat".into(),
        dataset: "CP".into(),
        scale: 512,
        feat_in: 128,
        feat_out: 128,
        ..Default::default()
    };
    let session = Session::prepare(&run).expect("session");
    let (dt, res) = time(|| session.simulate(&arch, false, None, 0).unwrap(), 5);
    t.row(&[
        "sim engine (GAT/CP 1/512, timing)".into(),
        format!("{:.1} ms", dt * 1e3),
        format!("{:.2} M instr/s", res.instructions as f64 / dt / 1e6),
    ]);

    // -- functional simulation ---------------------------------------------
    let mut frun = run.clone();
    frun.scale = 2048;
    frun.feat_in = 64;
    frun.feat_out = 64;
    let fsession = Session::prepare(&frun).expect("session");
    let x = fsession.make_input(1);
    let (dt, res) = time(|| fsession.simulate(&arch, true, Some(&x), 0).unwrap(), 3);
    t.row(&[
        "sim engine (GAT/CP 1/2048, functional)".into(),
        format!("{:.1} ms", dt * 1e3),
        format!("{:.2} M instr/s", res.instructions as f64 / dt / 1e6),
    ]);

    // -- tiling construction -------------------------------------------------
    let g = generators::power_law(40_000, 400_000, 1.1, 1.1, 0, 3);
    let (dt, tl) = time(|| tile(&g, TilingConfig::default()), 5);
    t.row(&[
        "tiling (40k V / 400k E, sparse+reorder)".into(),
        format!("{:.1} ms", dt * 1e3),
        format!("{:.1} M edges/s", tl.num_edges as f64 / dt / 1e6),
    ]);

    // -- functional GEMM ------------------------------------------------------
    let a = Tensor::filled(256, 128, 1.5);
    let w = vec![0.5f32; 128 * 128];
    let mut out = Tensor::zeros(256, 128);
    let (dt, _) = time(
        || {
            matmul(&a, &w, 128, 128, &mut out, false);
            out.data[0]
        },
        50,
    );
    let flops = 2.0 * 256.0 * 128.0 * 128.0;
    t.row(&[
        "functional GEMM 256x128x128".into(),
        format!("{:.1} us", dt * 1e6),
        format!("{:.2} GFLOP/s", flops / dt / 1e9),
    ]);

    print!("{}", t.render());
}
