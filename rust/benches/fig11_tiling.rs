//! Fig 11 reproduction: off-chip read reduction (left) and speedup
//! (right) of sparse tiling and sparse tiling + reordering over regular
//! tiling, per model, on cit-Patents.
//!
//! Paper's shape: 58× / 123× average read reduction and 48× / 135×
//! average speedup; weaker reductions for GAT/SAGE/GGNN (destination
//! embedding traffic can't be reduced) and weaker speedups for
//! GGNN/RGCN (BMM's on-chip latency dilutes the benefit).

use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::metrics::Table;
use zipper::models::ModelKind;
use zipper::tiling::{Reorder, TilingMode};
use zipper::util::stats::geomean;

fn main() {
    println!("== Fig 11: sparse tiling + reordering vs regular tiling (CP) ==");
    println!("paper: read reduction 58x (sparse) / 123x (+reorder); speedup 48x / 135x\n");
    let arch = ArchConfig::default();
    // finer tile grid accentuates blank-row waste, as in the paper
    let mut t = Table::new(&[
        "model", "regular MB", "sparse red. x", "+reorder red. x", "sparse speed x", "+reorder speed x",
    ]);
    let mut red_sp = Vec::new();
    let mut red_so = Vec::new();
    let mut spd_sp = Vec::new();
    let mut spd_so = Vec::new();

    for model in ModelKind::ALL {
        let mk = |mode, reorder| {
            // Larger graph + paper-proportioned tiles: the blank-row
            // waste regular tiling pays grows with |V| / src_part, so
            // the reduction factor is scale-dependent (see DESIGN.md §6).
            let mut run = RunConfig {
                model: model.name().into(),
                dataset: "CP".into(),
                scale: 16,
                feat_in: 128,
                feat_out: 128,
                ..Default::default()
            };
            run.tiling.mode = mode;
            run.tiling.reorder = reorder;
            run.tiling.dst_part = 2048;
            run.tiling.src_part = 2048;
            let session = Session::prepare(&run).expect("session");
            let res = session.simulate(&arch, false, None, 0).expect("simulate");
            (res.dram_read_bytes as f64, res.cycles as f64)
        };
        let (reg_b, reg_c) = mk(TilingMode::Regular, Reorder::None);
        let (sp_b, sp_c) = mk(TilingMode::Sparse, Reorder::None);
        let (so_b, so_c) = mk(TilingMode::Sparse, Reorder::InDegree);
        red_sp.push(reg_b / sp_b);
        red_so.push(reg_b / so_b);
        spd_sp.push(reg_c / sp_c);
        spd_so.push(reg_c / so_c);
        t.row(&[
            model.name().into(),
            format!("{:.1}", reg_b / 1e6),
            format!("{:.2}", reg_b / sp_b),
            format!("{:.2}", reg_b / so_b),
            format!("{:.2}", reg_c / sp_c),
            format!("{:.2}", reg_c / so_c),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\ngeomean read reduction: sparse {:.1}x, +reorder {:.1}x (paper 58x / 123x)",
        geomean(&red_sp),
        geomean(&red_so)
    );
    println!(
        "geomean speedup: sparse {:.1}x, +reorder {:.1}x (paper 48x / 135x)",
        geomean(&spd_sp),
        geomean(&spd_so)
    );
    // shape assertions: both optimizations help; reorder adds on top
    assert!(geomean(&red_sp) > 1.5);
    assert!(geomean(&red_so) >= geomean(&red_sp));
    assert!(geomean(&spd_sp) > 1.2);
    assert!(geomean(&spd_so) >= geomean(&spd_sp) * 0.95);
}
