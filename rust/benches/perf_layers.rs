//! Depth sweep for stacked-layer pipelines (the multi-layer serving
//! story): GCN/GAT/SAGE at depths {1, 2, 3} on SL/64, served through the
//! coordinator with a warm plan cache. Measures warm req/s per depth,
//! records the per-layer cycle/DRAM breakdown and the Fig 2-style
//! aggregate peak-UEM footprint, and asserts the compile-once contract:
//! warm multi-layer requests hit the plan cache on every request and
//! **tiling runs exactly once per plan** — never per layer, never on a
//! warm request. Emits `BENCH_layers.json`.
//!
//! ```bash
//! cargo bench --bench perf_layers            # SL/64 full sweep
//! cargo bench --bench perf_layers -- --smoke # tiny CI-sized run
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;
use zipper::config::{ArchConfig, RunConfig, ServingConfig};
use zipper::coordinator::{Coordinator, InferenceRequest, InferenceResponse};
use zipper::metrics::Table;
use zipper::plan::PlanCache;
use zipper::tiling::{self, Reorder, TilingConfig, TilingMode};
use zipper::util::json::Json;

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn request(model: &str, dataset: &str, scale: u64, depth: u32, id: u64) -> InferenceRequest {
    let run = RunConfig {
        model: model.into(),
        dataset: dataset.into(),
        scale,
        feat_in: 32,
        feat_out: 32,
        layers: depth,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 256,
            src_part: 256,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 7,
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    };
    InferenceRequest { id, run, input_seed: id % 4 }
}

fn serve(
    arch: ArchConfig,
    cache: &Arc<PlanCache>,
    model: &str,
    dataset: &str,
    scale: u64,
    depth: u32,
    n: u64,
) -> (Vec<InferenceResponse>, f64) {
    let serving = ServingConfig { exec_threads: 2, max_batch: 4, ..Default::default() };
    let mut c = Coordinator::with_serving(arch, 2, serving, Arc::clone(cache));
    let t0 = Instant::now();
    for i in 0..n {
        c.submit(request(model, dataset, scale, depth, i));
    }
    let mut resp = c.drain();
    let wall = t0.elapsed().as_secs_f64();
    resp.sort_by_key(|r| r.id);
    for r in &resp {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    }
    (resp, wall)
}

fn main() {
    let (dataset, scale, n_req) = if smoke() { ("CR", 16, 6u64) } else { ("SL", 64, 16u64) };
    let arch = ArchConfig::default();
    let mut table = Table::new(&[
        "model", "depth", "warm req/s", "cycles", "per-layer cycles", "peak UEM",
    ]);
    let mut rows: Vec<Json> = Vec::new();

    for model in ["gcn", "gat", "sage"] {
        for depth in [1u32, 2, 3] {
            let cache = Arc::new(PlanCache::new());
            // compile the plan once, single-threaded, and prove the
            // compile-once contract at depth: ONE tiling per plan,
            // shared by every layer stage — never one per layer
            let tiles_before = tiling::tile_invocations();
            let (plan, hit) = cache
                .get_or_compile(&request(model, dataset, scale, depth, 0).run)
                .expect("plan compiles");
            assert!(!hit);
            assert_eq!(plan.depth(), depth as usize);
            assert_eq!(
                tiling::tile_invocations() - tiles_before,
                1,
                "{model} depth {depth}: tiling must run exactly once per plan, \
                 regardless of depth"
            );

            let (first, _) = serve(arch, &cache, model, dataset, scale, depth, n_req);
            let tiles_warm_before = tiling::tile_invocations();
            let (warm, warm_wall) = serve(arch, &cache, model, dataset, scale, depth, n_req);
            assert_eq!(
                tiling::tile_invocations(),
                tiles_warm_before,
                "{model} depth {depth}: warm requests must never retile"
            );
            assert_eq!(cache.stats().entries, 1, "one plan serves every request");
            assert!(
                warm.iter().all(|r| r.plan_cache_hit),
                "{model} depth {depth}: warm multi-layer requests must hit the plan cache"
            );
            for (c, w) in first.iter().zip(&warm) {
                assert_eq!(
                    c.output_checksum, w.output_checksum,
                    "{model} depth {depth} id={}: warm output must be bit-identical",
                    c.id
                );
            }

            let r0 = &warm[0];
            assert_eq!(r0.layers.len(), depth as usize);
            assert_eq!(
                r0.sim_cycles,
                r0.layers.iter().map(|l| l.cycles).sum::<u64>(),
                "per-layer cycles must sum to the pipeline total"
            );
            let warm_rps = n_req as f64 / warm_wall;
            let per_layer: Vec<String> =
                r0.layers.iter().map(|l| l.cycles.to_string()).collect();
            table.row(&[
                model.to_string(),
                depth.to_string(),
                format!("{warm_rps:.1}"),
                r0.sim_cycles.to_string(),
                per_layer.join("+"),
                format!("{:.1} KB", r0.peak_uem_bytes as f64 / 1024.0),
            ]);
            let mut row = BTreeMap::new();
            row.insert("model".to_string(), Json::Str(model.to_string()));
            row.insert("depth".to_string(), num(depth as f64));
            row.insert("requests".to_string(), num(n_req as f64));
            row.insert("warm_req_per_s".to_string(), num(warm_rps));
            row.insert("sim_cycles".to_string(), num(r0.sim_cycles as f64));
            row.insert(
                "layer_cycles".to_string(),
                Json::Arr(r0.layers.iter().map(|l| num(l.cycles as f64)).collect()),
            );
            row.insert(
                "layer_dram_read_bytes".to_string(),
                Json::Arr(
                    r0.layers.iter().map(|l| num(l.dram_read_bytes as f64)).collect(),
                ),
            );
            row.insert("peak_uem_bytes".to_string(), num(r0.peak_uem_bytes as f64));
            row.insert("energy_j".to_string(), num(r0.energy_j));
            rows.push(Json::Obj(row));
        }
    }

    println!(
        "== stacked-layer pipelines ({dataset} 1/{scale}, {n_req} warm functional \
         requests per cell; tiling-once + warm-hit asserted) =="
    );
    print!("{}", table.render());

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf_layers".to_string()));
    root.insert("dataset".to_string(), Json::Str(dataset.to_string()));
    root.insert("scale".to_string(), num(scale as f64));
    root.insert("sweep".to_string(), Json::Arr(rows));
    let path = "BENCH_layers.json";
    std::fs::write(path, Json::Obj(root).to_string_pretty()).expect("write BENCH_layers.json");
    println!("wrote {path}");
}
