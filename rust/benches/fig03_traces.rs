//! Fig 3 reproduction: FLOP-efficiency / DRAM-bandwidth phase traces.
//!
//! Paper's shape: PageRank is GOP-dominated at ~0 FLOP efficiency; VGG is
//! GEMM-dominated near peak; GNNs (GAT, SAGE) interleave GEMM/ELW/GOP
//! phases and average ≥35% lower FLOP efficiency than VGG.
//!
//! Baselines use the analytic per-operator segments on the V100 model;
//! ZIPPER's own trace comes from the cycle simulator's windowed sampler.

use zipper::baselines::{refworkloads, whole_graph_ops, DeviceModel, DeviceSegment};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::metrics::{Phase, Table};
use zipper::models;

fn summarize(name: &str, segs: &[DeviceSegment], t: &mut Table) {
    let total: f64 = segs.iter().map(|s| s.seconds).sum();
    let mut phase_time = [0.0f64; 3]; // gemm, elw, gop
    let mut flop_eff = 0.0;
    let mut bw = 0.0;
    for s in segs {
        let idx = match s.phase {
            Phase::Gemm => 0,
            Phase::Elw => 1,
            _ => 2,
        };
        phase_time[idx] += s.seconds;
        flop_eff += s.flop_eff * s.seconds;
        bw += s.bw_util * s.seconds;
    }
    t.row(&[
        name.into(),
        format!("{:.1}", 100.0 * phase_time[0] / total),
        format!("{:.1}", 100.0 * phase_time[1] / total),
        format!("{:.1}", 100.0 * phase_time[2] / total),
        format!("{:.1}", 100.0 * flop_eff / total),
        format!("{:.1}", 100.0 * bw / total),
    ]);
}

fn main() {
    println!("== Fig 3: phase traces (V100 analytic baselines) ==");
    println!("paper: PR all-GOP @ ~0 FLOP eff; VGG all-GEMM near peak; GNNs mixed\n");
    let gpu = DeviceModel::gpu_dgl();
    // SL-scale graph for the GNNs / PR rows (paper uses Table 3 graphs)
    let (v, e) = (4_847_571u64, 43_369_619u64);
    let mut t = Table::new(&[
        "workload", "%time GEMM", "%time ELW", "%time GOP", "avg FLOP eff %", "avg DRAM util %",
    ]);
    summarize("PageRank/SL", &gpu.run(&refworkloads::pagerank(v, e), 0).segments, &mut t);
    summarize("VGG16@256", &gpu.run(&refworkloads::vgg16(256), 0).segments, &mut t);
    summarize("ResNet50@256", &gpu.run(&refworkloads::resnet50(256), 0).segments, &mut t);
    let gat = whole_graph_ops(&models::gat(), v, e, 128, 128);
    summarize("GAT/SL", &gpu.run(&gat, 0).segments, &mut t);
    let sage = whole_graph_ops(&models::sage(), v, e, 128, 128);
    summarize("SAGE/SL", &gpu.run(&sage, 0).segments, &mut t);
    print!("{}", t.render());

    // the figure's core claim: GNN flop eff well below VGG's
    let eff = |segs: &[DeviceSegment]| {
        let tt: f64 = segs.iter().map(|s| s.seconds).sum();
        segs.iter().map(|s| s.flop_eff * s.seconds).sum::<f64>() / tt
    };
    let vgg_eff = eff(&gpu.run(&refworkloads::vgg16(256), 0).segments);
    let gat_eff = eff(&gpu.run(&gat, 0).segments);
    println!(
        "\nVGG FLOP eff {:.1}% vs GAT {:.1}% (paper: GNN >= 35% lower) -> {}",
        vgg_eff * 100.0,
        gat_eff * 100.0,
        if gat_eff < 0.65 * vgg_eff { "holds" } else { "VIOLATED" }
    );
    assert!(gat_eff < 0.65 * vgg_eff);

    // ZIPPER's own interleaving trace (cycle-sim windowed sampler)
    println!("\n== ZIPPER trace (GAT on CP @ 1/512 scale, 1024-cycle windows) ==");
    let run = RunConfig {
        model: "gat".into(),
        dataset: "CP".into(),
        scale: 512,
        feat_in: 64,
        feat_out: 64,
        ..Default::default()
    };
    let session = Session::prepare(&run).expect("session");
    let res = session
        .simulate(&ArchConfig::default(), false, None, 1024)
        .expect("simulate");
    let mut counts = std::collections::BTreeMap::new();
    for s in &res.trace {
        *counts.entry(s.phase.tag()).or_insert(0usize) += 1;
    }
    println!("{} windows; dominant-phase histogram: {:?}", res.trace.len(), counts);
    let phases = counts.len();
    println!("distinct phases in trace: {phases} (paper: GNNs interleave all classes)");
    assert!(phases >= 3, "GAT must interleave GEMM/ELW/GOP/MEM phases");
    // print a compact timeline (first 40 windows)
    let line: String = res
        .trace
        .iter()
        .take(40)
        .map(|s| match s.phase {
            Phase::Gemm => 'G',
            Phase::Elw => 'e',
            Phase::Gop => 'o',
            Phase::Mem => 'm',
            Phase::Idle => '.',
        })
        .collect();
    println!("timeline (1 char / window): {line}");
}
