//! Table 5 reproduction: ZIPPER area breakdown at 16 nm.
//!
//! Paper: MU 1.00 mm², VU 0.06 mm² each, embedding memory 52.31 mm²,
//! tile hub 0.15 mm², total 53.58 mm² = 6.57% of the V100 die; on-chip
//! memory is 97.91% of the accelerator.

use zipper::area::{area, V100_DIE_MM2};
use zipper::config::ArchConfig;
use zipper::metrics::Table;

fn main() {
    println!("== Table 5: area breakdown ==\n");
    let arch = ArchConfig::default();
    let a = area(&arch);
    let mut t = Table::new(&["component", "mm^2", "% of total", "paper mm^2"]);
    let total = a.total_mm2();
    for (name, mm2, paper) in [
        ("1x MU (32x128)", a.mu_mm2, "1.00"),
        ("2x VU (8xSIMD32)", a.vu_mm2, "0.12"),
        ("Embedding Mem (21MB eDRAM)", a.uem_mm2, "52.31"),
        ("Tile Hub (256KB SRAM)", a.tile_hub_mm2, "0.15"),
    ] {
        t.row(&[
            name.into(),
            format!("{mm2:.2}"),
            format!("{:.2}%", 100.0 * mm2 / total),
            paper.into(),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        format!("{total:.2}"),
        "100%".into(),
        "53.58".into(),
    ]);
    print!("{}", t.render());
    println!(
        "\nmemory fraction: {:.2}% (paper 97.91%)",
        100.0 * a.memory_fraction()
    );
    println!(
        "vs V100 die ({V100_DIE_MM2} mm^2): {:.2}% (paper 6.57%)",
        100.0 * total / V100_DIE_MM2
    );
    assert!((total - 53.58).abs() < 0.05);
    assert!((a.memory_fraction() - 0.979).abs() < 0.005);
}
