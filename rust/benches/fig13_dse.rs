//! Fig 13 reproduction: design-space exploration over stream counts and
//! compute-unit counts (GAT + SAGE on cit-Patents), latencies normalized
//! to (2 s/eStreams, 1 MU, 2 VU).
//!
//! Paper's observations: (1) a sweet spot in the s/eStream count — more
//! streams help (up to 1.72×) then flatten/regress; (2) models differ in
//! unit sensitivity: GAT responds to both VU and MU, SAGE mostly to MU.

use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::metrics::Table;
use zipper::models::ModelKind;

fn simulate(session: &Session, streams: u32, mu: u32, vu: u32) -> u64 {
    let mut arch = ArchConfig::default();
    arch.s_streams = streams;
    arch.e_streams = streams;
    arch.mu_count = mu;
    arch.vu_count = vu;
    session.simulate(&arch, false, None, 0).expect("simulate").cycles
}

fn main() {
    println!("== Fig 13: DSE over streams x MU x VU (CP) ==");
    println!("paper: stream sweet spot (<=1.72x); GAT sensitive to VU+MU, SAGE to MU\n");

    for model in [ModelKind::Gat, ModelKind::Sage] {
        // enough tiles per partition that stream-level pipelining is the
        // binding constraint (the regime Fig 13 explores)
        let mut run = RunConfig {
            model: model.name().into(),
            dataset: "CP".into(),
            scale: 256,
            feat_in: 128,
            feat_out: 128,
            ..Default::default()
        };
        run.tiling.dst_part = 512;
        run.tiling.src_part = 512;
        let session = Session::prepare(&run).expect("session");
        let base = simulate(&session, 2, 1, 2) as f64;

        println!("-- {} (normalized to 2 streams / 1 MU / 2 VU) --", model.name());
        let mut t = Table::new(&["s/e streams", "1MU 2VU", "1MU 4VU", "2MU 2VU", "2MU 4VU"]);
        let mut best_speedup: f64 = 0.0;
        for streams in [1u32, 2, 4, 8, 16] {
            let mut cells = vec![streams.to_string()];
            for (mu, vu) in [(1u32, 2u32), (1, 4), (2, 2), (2, 4)] {
                let c = simulate(&session, streams, mu, vu) as f64;
                best_speedup = best_speedup.max(base / c);
                cells.push(format!("{:.3}", c / base));
            }
            t.row(&cells);
        }
        print!("{}", t.render());
        println!("best speedup over baseline config: {best_speedup:.2}x\n");
    }

    // sensitivity check (paper observation 2)
    let sens = |model: ModelKind, mu: u32, vu: u32| {
        let mut run = RunConfig {
            model: model.name().into(),
            dataset: "CP".into(),
            scale: 256,
            feat_in: 128,
            feat_out: 128,
            ..Default::default()
        };
        run.tiling.dst_part = 512;
        run.tiling.src_part = 512;
        let session = Session::prepare(&run).expect("session");
        let base = simulate(&session, 4, 1, 2) as f64;
        base / simulate(&session, 4, mu, vu) as f64
    };
    let sage_mu = sens(ModelKind::Sage, 2, 2);
    let sage_vu = sens(ModelKind::Sage, 1, 4);
    println!(
        "SAGE: 2x MU -> {sage_mu:.3}x, 2x VU -> {sage_vu:.3}x \
         (paper: SAGE only changes with MU)"
    );
    assert!(
        sage_mu > sage_vu - 0.02,
        "SAGE must be at least as MU-sensitive as VU-sensitive"
    );
}
