//! Fig 14 reproduction: ZIPPER vs HyGCN vs PyG-CPU/GPU on a full
//! two-layer GCN over the four citation graphs.
//!
//! Paper's shape: ZIPPER (with software reordering) beats HyGCN in both
//! latency and energy on all four graphs; with reordering disabled,
//! ZIPPER falls slightly behind HyGCN (its fixed two-stage pipeline is
//! specialized for exactly this model) but stays ahead of PyG-GPU.
//!
//! Feature widths follow the standard citation setups (input → 128 →
//! #classes). Reddit is scaled 1/64 (DESIGN.md §5); the small citation
//! graphs run at full size.

use zipper::baselines::hygcn::{run_gcn, HygcnConfig};
use zipper::baselines::{whole_graph_ops, DeviceModel};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::energy::EnergyModel;
use zipper::graph::datasets;
use zipper::metrics::Table;
use zipper::models;
use zipper::tiling::Reorder;

struct Case {
    id: &'static str,
    scale: u64,
    feats: [u32; 3], // input, hidden, classes
}

fn zipper_two_layer(case: &Case, reorder: Reorder, arch: &ArchConfig) -> (f64, f64) {
    let mut total_s = 0.0;
    let mut total_j = 0.0;
    for l in 0..2 {
        let mut run = RunConfig {
            model: "gcn".into(),
            dataset: case.id.into(),
            scale: case.scale,
            feat_in: case.feats[l],
            feat_out: case.feats[l + 1],
            ..Default::default()
        };
        run.tiling.reorder = reorder;
        run.tiling.dst_part = 1024;
        run.tiling.src_part = 1024;
        let session = Session::prepare(&run).expect("session");
        let res = session.simulate(arch, false, None, 0).expect("simulate");
        total_s += res.seconds(arch);
        total_j += EnergyModel::default().evaluate(&res.counters, arch.freq_hz).total_j();
    }
    (total_s, total_j)
}

fn main() {
    println!("== Fig 14: vs HyGCN on 2-layer GCN (citation graphs) ==");
    println!("paper: ZIPPER beats HyGCN end-to-end; w/o reorder slightly behind HyGCN,\nstill ahead of PyG-GPU\n");
    let arch = ArchConfig::default();
    let cases = [
        Case { id: "CR", scale: 1, feats: [1433, 128, 7] },
        Case { id: "CS", scale: 1, feats: [3703, 128, 6] },
        Case { id: "PB", scale: 1, feats: [500, 128, 3] },
        Case { id: "RD", scale: 64, feats: [602, 128, 41] },
    ];
    let mut t = Table::new(&[
        "dataset", "ZIPPER ms", "ZIPPER (no-reorder) ms", "HyGCN ms", "PyG-GPU ms",
        "Z vs HyGCN", "Z(nr) vs HyGCN",
    ]);
    for case in &cases {
        let (z_s, z_j) = zipper_two_layer(case, Reorder::InDegree, &arch);
        let (znr_s, _) = zipper_two_layer(case, Reorder::None, &arch);

        // HyGCN at the same (scaled) graph size
        let spec = datasets::by_id(case.id).unwrap();
        let g = spec.instantiate(case.scale, 42);
        let (v, e) = (g.num_vertices() as u64, g.num_edges());
        let feats: Vec<u64> = case.feats.iter().map(|&f| f as u64).collect();
        let hy = run_gcn(&HygcnConfig::default(), v, e, &feats);

        // PyG baselines over both layers
        let gpu = DeviceModel::gpu_dgl();
        let mut pyg_gpu = 0.0;
        for l in 0..2 {
            let ops = whole_graph_ops(&models::gcn(), v, e, feats[l], feats[l + 1]);
            pyg_gpu += gpu.run(&ops, 0).seconds;
        }

        t.row(&[
            case.id.into(),
            format!("{:.3}", z_s * 1e3),
            format!("{:.3}", znr_s * 1e3),
            format!("{:.3}", hy.seconds * 1e3),
            format!("{:.3}", pyg_gpu * 1e3),
            format!("{:.2}x", hy.seconds / z_s),
            format!("{:.2}x", hy.seconds / znr_s),
        ]);
        // shape: with reorder ZIPPER wins; w/o reorder it must not beat
        // its reordered self and should stay ahead of PyG-GPU
        assert!(z_s <= znr_s * 1.001, "{}: reorder must not hurt", case.id);
        assert!(znr_s < pyg_gpu, "{}: ZIPPER(nr) must beat PyG-GPU", case.id);
        let _ = z_j;
    }
    print!("{}", t.render());

    // energy comparison on Cora
    let case = &cases[0];
    let (_, z_j) = zipper_two_layer(case, Reorder::InDegree, &arch);
    let spec = datasets::by_id(case.id).unwrap();
    let g = spec.instantiate(1, 42);
    let hy = run_gcn(
        &HygcnConfig::default(),
        g.num_vertices() as u64,
        g.num_edges(),
        &[1433, 128, 7],
    );
    println!(
        "\nCora energy: ZIPPER {:.4} mJ vs HyGCN {:.4} mJ ({:.2}x)",
        z_j * 1e3,
        hy.energy_j * 1e3,
        hy.energy_j / z_j
    );
}
