//! Fig 12 reproduction: E2V compiler-optimization speedup on GAT and
//! SAGE (cit-Patents), on ZIPPER and on the GPU baseline.
//!
//! Paper: GAT 1.87× / SAGE 1.03× on ZIPPER; 2.36× / 1.62× for the same
//! rewrite applied to DGL on the V100.

use zipper::baselines::{whole_graph_ops, DeviceModel};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::ir::e2v;
use zipper::metrics::Table;
use zipper::models::ModelKind;

fn main() {
    println!("== Fig 12: E2V compiler optimization (naive vs optimized, CP) ==");
    println!("paper: ZIPPER GAT 1.87x SAGE 1.03x; GPU GAT 2.36x SAGE 1.62x\n");
    let arch = ArchConfig::default();
    let mut t = Table::new(&["model", "ZIPPER naive ms", "ZIPPER opt ms", "ZIPPER x", "GPU x"]);

    let mut zipper_gat_x = 0.0;
    for model in [ModelKind::Gat, ModelKind::Sage] {
        let mk = |e2v_on: bool| {
            let run = RunConfig {
                model: model.name().into(),
                dataset: "CP".into(),
                scale: 1024,
                feat_in: 128,
                feat_out: 128,
                e2v: e2v_on,
                ..Default::default()
            };
            let session = Session::prepare(&run).expect("session");
            let res = session.simulate(&arch, false, None, 0).expect("simulate");
            (res.seconds(&arch), session.graph().num_vertices() as u64, session.graph().num_edges())
        };
        let (naive_s, v, e) = mk(false);
        let (opt_s, _, _) = mk(true);
        let zx = naive_s / opt_s;
        if model == ModelKind::Gat {
            zipper_gat_x = zx;
        }

        // GPU: same rewrite applied to the whole-graph operator list
        let gpu = DeviceModel::gpu_dgl();
        let naive_ops = whole_graph_ops(&model.build(), v, e, 128, 128);
        let (opt_graph, _) = e2v::optimize(&model.build());
        let opt_ops = whole_graph_ops(&opt_graph, v, e, 128, 128);
        let gx = gpu.run(&naive_ops, 0).seconds / gpu.run(&opt_ops, 0).seconds;

        t.row(&[
            model.name().into(),
            format!("{:.3}", naive_s * 1e3),
            format!("{:.3}", opt_s * 1e3),
            format!("{zx:.2}"),
            format!("{gx:.2}"),
        ]);
    }
    print!("{}", t.render());
    println!("\nshape check: GAT benefits substantially, SAGE mildly (paper's ordering)");
    assert!(zipper_gat_x > 1.2, "GAT E2V speedup must be substantial");
}
