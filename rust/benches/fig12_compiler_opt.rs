//! Fig 12 reproduction: E2V compiler-optimization speedup on GAT and
//! SAGE (cit-Patents), on ZIPPER and on the GPU baseline — plus the
//! pipeline-optimizer per-pass attribution table (DESIGN.md §3.7).
//!
//! Paper: GAT 1.87× / SAGE 1.03× on ZIPPER; 2.36× / 1.62× for the same
//! rewrite applied to DGL on the V100.
//!
//! `--smoke` runs only the (fast) per-pass attribution section and
//! asserts the optimizer's contract: every pass's cycle delta is ≥ 0
//! (no pass may regress), the all-passes depth-3 GCN pipeline executes
//! strictly fewer instructions than plain E2v, and every tier stays
//! bit-exact with the unoptimized plan on the cycle engine.

use zipper::baselines::{whole_graph_ops, DeviceModel};
use zipper::compiler::PassSet;
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::ir::e2v;
use zipper::metrics::Table;
use zipper::models::ModelKind;
use zipper::plan::ExecPlan;

fn fig12_section(arch: &ArchConfig) {
    println!("== Fig 12: E2V compiler optimization (naive vs optimized, CP) ==");
    println!("paper: ZIPPER GAT 1.87x SAGE 1.03x; GPU GAT 2.36x SAGE 1.62x\n");
    let mut t = Table::new(&["model", "ZIPPER naive ms", "ZIPPER opt ms", "ZIPPER x", "GPU x"]);

    let mut zipper_gat_x = 0.0;
    for model in [ModelKind::Gat, ModelKind::Sage] {
        let mk = |e2v_on: bool| {
            let run = RunConfig {
                model: model.name().into(),
                dataset: "CP".into(),
                scale: 1024,
                feat_in: 128,
                feat_out: 128,
                e2v: e2v_on,
                ..Default::default()
            };
            let session = Session::prepare(&run).expect("session");
            let res = session.simulate(arch, false, None, 0).expect("simulate");
            (res.seconds(arch), session.graph().num_vertices() as u64, session.graph().num_edges())
        };
        let (naive_s, v, e) = mk(false);
        let (opt_s, _, _) = mk(true);
        let zx = naive_s / opt_s;
        if model == ModelKind::Gat {
            zipper_gat_x = zx;
        }

        // GPU: same rewrite applied to the whole-graph operator list
        let gpu = DeviceModel::gpu_dgl();
        let naive_ops = whole_graph_ops(&model.build(), v, e, 128, 128);
        let (opt_graph, _) = e2v::optimize(&model.build());
        let opt_ops = whole_graph_ops(&opt_graph, v, e, 128, 128);
        let gx = gpu.run(&naive_ops, 0).seconds / gpu.run(&opt_ops, 0).seconds;

        t.row(&[
            model.name().into(),
            format!("{:.3}", naive_s * 1e3),
            format!("{:.3}", opt_s * 1e3),
            format!("{zx:.2}"),
            format!("{gx:.2}"),
        ]);
    }
    print!("{}", t.render());
    println!("\nshape check: GAT benefits substantially, SAGE mildly (paper's ordering)");
    assert!(zipper_gat_x > 1.2, "GAT E2V speedup must be substantial");
}

fn pass_attribution(arch: &ArchConfig, model: ModelKind, layers: u32, assert_contract: bool) {
    let mk_run = |passes: PassSet| RunConfig {
        model: model.name().into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 32,
        feat_out: 32,
        layers,
        passes,
        ..Default::default()
    };
    let instr_count = |p: &ExecPlan| {
        p.stages.iter().map(|s| s.program.instruction_count()).sum::<usize>()
    };

    let baseline = ExecPlan::compile(&mk_run(PassSet::none())).expect("baseline plan");
    let base_instrs = instr_count(&baseline);
    let base_cycles =
        baseline.simulate(arch, false, None, 0).expect("baseline timing").cycles;
    let x = baseline.make_input(7);
    let base_out = baseline
        .simulate(arch, true, Some(&x), 0)
        .expect("baseline functional")
        .output
        .expect("baseline output");

    println!(
        "\n== Pipeline optimizer: per-pass attribution ({} depth-{layers}, CR/16) ==",
        model.name()
    );
    println!("E2v baseline: {base_instrs} instructions, {base_cycles} cycles\n");
    let mut t = Table::new(&[
        "pass", "insns", "d insns", "cycles", "d cycles", "removed", "fused", "hoisted",
        "freed",
    ]);
    let tiers = PassSet::NAMED.iter().copied().chain([("all", PassSet::all())]);
    for (name, passes) in tiers {
        let plan = ExecPlan::compile(&mk_run(passes)).expect("optimized plan");
        let instrs = instr_count(&plan);
        let cycles = plan.simulate(arch, false, None, 0).expect("timing").cycles;
        let total = plan
            .opt_report
            .as_ref()
            .map(|r| {
                r.passes.iter().fold([0usize; 4], |acc, p| {
                    [
                        acc[0] + p.report.removed,
                        acc[1] + p.report.fused,
                        acc[2] + p.report.hoisted,
                        acc[3] + p.report.freed,
                    ]
                })
            })
            .unwrap_or([0; 4]);
        t.row(&[
            name.into(),
            instrs.to_string(),
            format!("{}", base_instrs as i64 - instrs as i64),
            cycles.to_string(),
            format!("{}", base_cycles as i64 - cycles as i64),
            total[0].to_string(),
            total[1].to_string(),
            total[2].to_string(),
            total[3].to_string(),
        ]);
        if assert_contract {
            assert!(
                cycles <= base_cycles,
                "pass {name} regressed cycles: {cycles} > {base_cycles}"
            );
            assert!(
                instrs <= base_instrs,
                "pass {name} grew the pipeline: {instrs} > {base_instrs}"
            );
            if name == "all" {
                assert!(
                    instrs < base_instrs,
                    "all passes on a depth-{layers} {} pipeline must drop instructions",
                    model.name()
                );
            }
            let out = plan
                .simulate(arch, true, Some(&x), 0)
                .expect("optimized functional")
                .output
                .expect("optimized output");
            assert_eq!(out, base_out, "pass {name} is not bit-exact with E2v");
        }
    }
    print!("{}", t.render());
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let arch = ArchConfig::default();
    if !smoke {
        fig12_section(&arch);
        // attribution on a weight-bearing model too (hoist is live here)
        pass_attribution(&arch, ModelKind::Gat, 2, false);
    }
    // the asserted contract tier: depth-3 GCN (ISSUE acceptance shape)
    pass_attribution(&arch, ModelKind::Gcn, 3, true);
    if smoke {
        println!("\nsmoke ok: no pass regresses cycles; all-passes shrinks the pipeline");
    }
}
