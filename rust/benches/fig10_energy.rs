//! Fig 10 reproduction: energy reduction over DGL-CPU / DGL-GPU.
//!
//! Paper headline: CPU consumes 147× and GPU 4.85× ZIPPER's energy on
//! average — dedicated units + tiling-reduced memory traffic vs
//! general-purpose silicon at 170–300 W.

use zipper::baselines::{whole_graph_ops, DeviceModel};
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::energy::EnergyModel;
use zipper::graph::datasets::TABLE3;
use zipper::metrics::Table;
use zipper::models::ModelKind;
use zipper::util::stats::geomean;

fn main() {
    println!("== Fig 10: energy reduction vs DGL-CPU / DGL-GPU ==");
    println!("paper: CPU 147x, GPU 4.85x ZIPPER's energy on average\n");
    let arch = ArchConfig::default();
    let scale = 1024u64;
    let mut t = Table::new(&["model", "dataset", "ZIPPER mJ", "CPU x", "GPU x"]);
    let mut cpu_all = Vec::new();
    let mut gpu_all = Vec::new();

    for model in ModelKind::ALL {
        for spec in &TABLE3 {
            let run = RunConfig {
                model: model.name().into(),
                dataset: spec.id.into(),
                scale,
                feat_in: 128,
                feat_out: 128,
                ..Default::default()
            };
            let session = Session::prepare(&run).expect("session");
            let res = session.simulate(&arch, false, None, 0).expect("simulate");
            let zipper_j = EnergyModel::default()
                .evaluate(&res.counters, arch.freq_hz)
                .total_j();
            let (v, e) = (session.graph().num_vertices() as u64, session.graph().num_edges());
            let ops = whole_graph_ops(&model.build(), v, e, 128, 128);
            let cpu_j = DeviceModel::cpu_dgl().run(&ops, 0).energy_j;
            let gpu_j = DeviceModel::gpu_dgl().run(&ops, 0).energy_j;
            cpu_all.push(cpu_j / zipper_j);
            gpu_all.push(gpu_j / zipper_j);
            t.row(&[
                model.name().into(),
                spec.id.into(),
                format!("{:.4}", zipper_j * 1e3),
                format!("{:.0}", cpu_j / zipper_j),
                format!("{:.2}", gpu_j / zipper_j),
            ]);
        }
    }
    print!("{}", t.render());
    let cpu_avg = geomean(&cpu_all);
    let gpu_avg = geomean(&gpu_all);
    println!("\ngeomean energy ratio CPU/ZIPPER: {cpu_avg:.0}x (paper 147x)");
    println!("geomean energy ratio GPU/ZIPPER: {gpu_avg:.2}x (paper 4.85x)");
    assert!(cpu_avg > 20.0);
    assert!(gpu_avg > 1.0);
    assert!(cpu_avg > 5.0 * gpu_avg, "CPU gap >> GPU gap (shape of Fig 10)");
}
