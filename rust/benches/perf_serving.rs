//! Serving-throughput bench for the compile-once hot path.
//!
//! Measures requests/sec through the coordinator with a **cold** plan
//! cache (every request may compile a plan) vs a **warm** cache (every
//! request reuses a shared `Arc<ExecPlan>` and a per-worker scratch),
//! across worker counts; times plan compilation vs cache lookup
//! directly; sweeps **batched + tile-parallel** serving
//! (`--max-batch` × `--exec-threads`) against sequential warm serving on
//! the largest bundled dataset, asserting bit-identical per-request
//! outputs for every combination and ≥ 2× throughput at 4 exec threads;
//! and runs a **sustained-load open-loop** scenario against the
//! always-on `ZipperService` (seeded deterministic arrival process, not
//! wall-clock-derived) at a steady, an overload, and a tight-deadline
//! operating point, asserting the accounting identity
//! `submitted == completed + failed + rejected` (nothing lost, nothing
//! hung) and reporting tail latency + shed rate. Emits
//! `BENCH_serving.json` so future PRs have a trajectory for the serving
//! hot path.
//!
//! ```bash
//! cargo bench --bench perf_serving            # full run (asserts 2x)
//! cargo bench --bench perf_serving -- --smoke # tiny CI-sized soak
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};
use zipper::config::{ArchConfig, OverflowPolicy, RunConfig, ServingConfig};
use zipper::coordinator::{Coordinator, InferenceRequest, InferenceResponse, ZipperService};
use zipper::metrics::Table;
use zipper::plan::{ExecPlan, PlanCache};
use zipper::tiling::{tile, Reorder, TilingConfig, TilingMode};
use zipper::util::json::Json;
use zipper::util::Rng;

fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Requests per serving pass (`--smoke` = CI-sized tiny run).
fn n_requests() -> u64 {
    if smoke() {
        20
    } else {
        60
    }
}

fn request(i: u64) -> InferenceRequest {
    let models = ["gcn", "gat", "sage", "ggnn", "rgcn"];
    let datasets = ["CR", "CS", "PB"];
    let run = RunConfig {
        model: models[i as usize % models.len()].into(),
        dataset: datasets[i as usize % datasets.len()].into(),
        scale: 4,
        feat_in: 32,
        feat_out: 32,
        tiling: TilingConfig {
            dst_part: 256,
            src_part: 256,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        // timing-only: the serving hot path benches the scheduler +
        // plan reuse, not the functional executor
        functional: false,
        seed: 7,
        layers: 1,
        hidden: Vec::new(),
        serving: Default::default(),
        kernels: Default::default(),
        shards: 1,
        overlap: false,
    };
    InferenceRequest { id: i, run, input_seed: i }
}

/// Serve one batch with `threads` tiling threads per cold compile;
/// returns (wall seconds, error count, warm hits, mean cold prepare s).
fn serve(
    arch: ArchConfig,
    workers: usize,
    cache: &Arc<PlanCache>,
    threads: u32,
) -> (f64, usize, usize, f64) {
    let mut c = Coordinator::with_cache(arch, workers, Arc::clone(cache));
    let t0 = Instant::now();
    for i in 0..n_requests() {
        let mut req = request(i);
        req.run.tiling.threads = threads;
        c.submit(req);
    }
    let resp = c.drain();
    let wall = t0.elapsed().as_secs_f64();
    let errors = resp.iter().filter(|r| r.error.is_some()).count();
    let warm = resp.iter().filter(|r| r.plan_cache_hit).count();
    let cold: Vec<f64> = resp
        .iter()
        .filter(|r| !r.plan_cache_hit && r.error.is_none())
        .map(|r| r.prepare_seconds)
        .collect();
    let prep_mean = if cold.is_empty() {
        0.0
    } else {
        cold.iter().sum::<f64>() / cold.len() as f64
    };
    (wall, errors, warm, prep_mean)
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// One open-loop operating point against the always-on service:
/// arrivals follow a seeded exponential inter-arrival process
/// (deterministic offered load — the gap sequence depends only on
/// `seed`, never on the wall clock), submission never waits for
/// completions, and every ticket is awaited afterwards so response
/// accounting is exact.
#[allow(clippy::too_many_arguments)]
fn open_loop_point(
    arch: ArchConfig,
    label: &str,
    workers: usize,
    serving: ServingConfig,
    n: u64,
    mean_gap_us: f64,
    seed: u64,
    table: &mut Table,
) -> (zipper::coordinator::ServiceMetrics, Json) {
    // warm the plan: the scenario measures the runtime, not compilation
    let run = {
        let mut r = request(0).run;
        r.model = "gcn".into();
        r.dataset = "CR".into();
        r
    };
    let cache = Arc::new(PlanCache::new());
    cache.get_or_compile(&run).expect("precompile");
    let svc = ZipperService::new(arch, workers, serving, Arc::clone(&cache)).expect("service");

    let mut rng = Rng::new(seed);
    let mut tickets = Vec::with_capacity(n as usize);
    let t0 = Instant::now();
    for i in 0..n {
        tickets.push(svc.submit(InferenceRequest { id: i, run: run.clone(), input_seed: i }));
        if mean_gap_us > 0.0 {
            let gap = -(1.0 - rng.next_f64()).ln() * mean_gap_us;
            let gap_us = gap.min(mean_gap_us * 8.0) as u64;
            if gap_us > 0 {
                std::thread::sleep(Duration::from_micros(gap_us));
            }
        }
    }
    let submit_wall = t0.elapsed().as_secs_f64();

    // every submitted request must resolve to exactly one response
    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut shed = 0u64;
    for t in tickets {
        let r = t.wait();
        if r.reject.is_some() {
            shed += 1;
        } else if r.error.is_some() {
            failed += 1;
        } else {
            completed += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = svc.shutdown(Duration::from_secs(120));
    assert!(report.graceful, "{label}: backlog must drain within grace");
    let m = svc.metrics();
    assert_eq!(m.submitted, n, "{label}: submitted accounting");
    assert_eq!(
        m.completed + m.failed + m.rejected_total(),
        n,
        "{label}: submitted == completed + failed + rejected must hold exactly"
    );
    assert_eq!((completed, failed, shed), (m.completed, m.failed, m.rejected_total()));
    assert_eq!(failed, 0, "{label}: no request may fail with an error");

    table.row(&[
        label.to_string(),
        format!("{n}"),
        format!("{completed}"),
        format!("{:.1}%", m.shed_rate() * 100.0),
        format!("{}", m.latency_p50_us),
        format!("{}", m.latency_p95_us),
        format!("{}", m.latency_p99_us),
        format!("{}", m.peak_queue_depth),
        format!("{:.1}", m.mean_batch_size()),
    ]);
    let mut row = BTreeMap::new();
    row.insert("label".to_string(), Json::Str(label.to_string()));
    row.insert("workers".to_string(), num(workers as f64));
    row.insert("requests".to_string(), num(n as f64));
    row.insert("mean_gap_us".to_string(), num(mean_gap_us));
    row.insert("arrival_seed".to_string(), num(seed as f64));
    row.insert("submit_wall_s".to_string(), num(submit_wall));
    row.insert("wall_s".to_string(), num(wall));
    row.insert("completed".to_string(), num(m.completed as f64));
    row.insert("rejected_queue_full".to_string(), num(m.rejected_queue_full as f64));
    row.insert(
        "rejected_deadline".to_string(),
        num((m.rejected_deadline + m.shed_deadline) as f64),
    );
    row.insert("rejected_shutdown".to_string(), num(m.rejected_shutdown as f64));
    row.insert("shed_rate".to_string(), num(m.shed_rate()));
    row.insert("latency_p50_us".to_string(), num(m.latency_p50_us as f64));
    row.insert("latency_p95_us".to_string(), num(m.latency_p95_us as f64));
    row.insert("latency_p99_us".to_string(), num(m.latency_p99_us as f64));
    row.insert("latency_max_us".to_string(), num(m.latency_max_us as f64));
    row.insert("peak_queue_depth".to_string(), num(m.peak_queue_depth as f64));
    row.insert("mean_batch_size".to_string(), num(m.mean_batch_size()));
    (m, Json::Obj(row))
}

fn main() {
    let arch = ArchConfig::default();
    let n_req = n_requests();
    let mut table = Table::new(&[
        "workers", "cold req/s", "warm req/s", "speedup", "warm hits",
    ]);
    let mut rows: Vec<Json> = Vec::new();

    for workers in [1usize, 2, 4, 8] {
        let cache = Arc::new(PlanCache::new());
        let (cold_wall, cold_err, _, _) = serve(arch, workers, &cache, 1);
        assert_eq!(cold_err, 0, "cold pass had errors");
        // warm pass: same requests, plans already compiled
        let (warm_wall, warm_err, warm_hits, _) = serve(arch, workers, &cache, 1);
        assert_eq!(warm_err, 0, "warm pass had errors");
        assert_eq!(
            warm_hits as u64, n_req,
            "warm pass must hit the plan cache on every request"
        );
        let cold_rps = n_req as f64 / cold_wall;
        let warm_rps = n_req as f64 / warm_wall;
        table.row(&[
            workers.to_string(),
            format!("{cold_rps:.1}"),
            format!("{warm_rps:.1}"),
            format!("{:.2}x", warm_rps / cold_rps),
            format!("{warm_hits}/{n_req}"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("workers".to_string(), num(workers as f64));
        row.insert("requests".to_string(), num(n_req as f64));
        row.insert("cold_wall_s".to_string(), num(cold_wall));
        row.insert("warm_wall_s".to_string(), num(warm_wall));
        row.insert("cold_req_per_s".to_string(), num(cold_rps));
        row.insert("warm_req_per_s".to_string(), num(warm_rps));
        row.insert("warm_speedup".to_string(), num(warm_rps / cold_rps));
        row.insert("plan_entries".to_string(), num(cache.stats().entries as f64));
        rows.push(Json::Obj(row));
    }

    // direct cost of the decisions the cache skips: compile vs lookup
    let cache = PlanCache::new();
    let cfg = request(0).run;
    let t0 = Instant::now();
    cache.get_or_compile(&cfg).expect("compile");
    let compile_s = t0.elapsed().as_secs_f64();
    let lookups = 1_000u32;
    let t0 = Instant::now();
    for _ in 0..lookups {
        cache.get_or_compile(&cfg).expect("lookup");
    }
    let lookup_s = t0.elapsed().as_secs_f64() / lookups as f64;

    // parallel tiling: the cold-phase latency lever. Time tile() on a
    // larger graph across thread counts (identical partitions asserted),
    // then measure end-to-end cold prepare_seconds at 1 vs 4 threads.
    let mut trun = request(0).run;
    trun.dataset = "CP".into();
    let tiling_scale: u64 = if smoke() { 512 } else { 64 };
    trun.scale = tiling_scale;
    trun.tiling.threads = 1;
    let base_plan = ExecPlan::compile(&trun).expect("compile");
    let mut thr_table = Table::new(&["tiling threads", "tile ms", "speedup"]);
    let mut thr_rows: Vec<Json> = Vec::new();
    let mut serial_s = 0.0;
    for threads in [1u32, 2, 4, 8] {
        let cfg = TilingConfig { threads, ..trun.tiling };
        let reps = 3;
        let t0 = Instant::now();
        let mut tl = tile(&base_plan.graph, cfg);
        for _ in 1..reps {
            tl = tile(&base_plan.graph, cfg);
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        assert_eq!(
            tl.partitions, base_plan.tiling.partitions,
            "threads={threads} must produce the identical tiling"
        );
        if threads == 1 {
            serial_s = dt;
        }
        thr_table.row(&[
            threads.to_string(),
            format!("{:.1}", dt * 1e3),
            format!("{:.2}x", serial_s / dt),
        ]);
        let mut row = BTreeMap::new();
        row.insert("threads".to_string(), num(threads as f64));
        row.insert("tile_s".to_string(), num(dt));
        thr_rows.push(Json::Obj(row));
    }
    let (_, err1, _, prep1) = serve(arch, 4, &Arc::new(PlanCache::new()), 1);
    let (_, err4, _, prep4) = serve(arch, 4, &Arc::new(PlanCache::new()), 4);
    assert_eq!((err1, err4), (0, 0), "threaded cold passes had errors");

    // ---- batched + tile-parallel vs sequential warm serving --------------
    // Functional requests sharing one plan on the largest bundled
    // dataset (SL, scaled): sequential warm serving pays a timing
    // simulation + a one-lane functional pass per request; batched
    // serving amortizes the timing sim and the LD.SRC/LD.DST tile
    // traversal across the batch and shards tiles over exec threads.
    // Outputs must be bit-identical for every combination.
    let (batch_dataset, batch_scale, batch_requests) =
        if smoke() { ("CR", 16, 12u64) } else { ("SL", 64, 32u64) };
    let batch_req = |i: u64| {
        let mut run = request(0).run;
        run.model = "gcn".into();
        run.dataset = batch_dataset.into();
        run.scale = batch_scale;
        run.functional = true;
        InferenceRequest { id: i, run, input_seed: i % 4 }
    };
    let serve_batched = |serving: ServingConfig,
                         cache: &Arc<PlanCache>|
     -> (Vec<InferenceResponse>, f64) {
        let mut c = Coordinator::with_serving(arch, 4, serving, Arc::clone(cache));
        let t0 = Instant::now();
        for i in 0..batch_requests {
            c.submit(batch_req(i));
        }
        let mut resp = c.drain();
        let wall = t0.elapsed().as_secs_f64();
        resp.sort_by_key(|r| r.id);
        for r in &resp {
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        }
        (resp, wall)
    };
    let bcache = Arc::new(PlanCache::new());
    let seq_cfg = ServingConfig { exec_threads: 1, max_batch: 1, ..Default::default() };
    let _ = serve_batched(seq_cfg, &bcache); // cold pass compiles the plan
    let (seq_resp, seq_wall) = serve_batched(seq_cfg, &bcache);
    let seq_rps = batch_requests as f64 / seq_wall;
    let mut bt = Table::new(&["exec threads", "max batch", "req/s", "vs sequential"]);
    let mut brows: Vec<Json> = Vec::new();
    let mut speedup_4x8 = 0.0;
    for exec_threads in [1u32, 2, 4] {
        for max_batch in [1u32, 3, 8] {
            let serving = ServingConfig { exec_threads, max_batch, ..Default::default() };
            let (resp, wall) = serve_batched(serving, &bcache);
            for (r, s) in resp.iter().zip(&seq_resp) {
                assert_eq!(
                    r.output_checksum, s.output_checksum,
                    "threads={exec_threads} batch={max_batch} id={}: batched output \
                     must be bit-identical to sequential",
                    r.id
                );
                assert_eq!(r.sim_cycles, s.sim_cycles);
            }
            let rps = batch_requests as f64 / wall;
            let speedup = rps / seq_rps;
            if (exec_threads, max_batch) == (4, 8) {
                speedup_4x8 = speedup;
            }
            bt.row(&[
                exec_threads.to_string(),
                max_batch.to_string(),
                format!("{rps:.1}"),
                format!("{speedup:.2}x"),
            ]);
            let mut row = BTreeMap::new();
            row.insert("exec_threads".to_string(), num(exec_threads as f64));
            row.insert("max_batch".to_string(), num(max_batch as f64));
            row.insert("req_per_s".to_string(), num(rps));
            row.insert("speedup_vs_sequential".to_string(), num(speedup));
            brows.push(Json::Obj(row));
        }
    }
    if !smoke() {
        // acceptance floor for the batched serving path (skipped in the
        // tiny CI smoke, where thread overhead dominates the workload)
        assert!(
            speedup_4x8 >= 2.0,
            "batched serving at 4 exec threads / max_batch 8 must be ≥2x \
             sequential warm throughput, got {speedup_4x8:.2}x"
        );
    }

    // ---- sustained-load open-loop serving (always-on runtime) ------------
    // Three operating points through the `ZipperService`: a steady point
    // (offered load below capacity, queue never fills — zero sheds), an
    // overload point (burst arrivals into a queue_cap-4 admission queue —
    // must shed with structured QueueFull, never hang, never lose a
    // response), and a tight-deadline point (burst into an unbounded-ish
    // queue with a 2 ms deadline — the queue wait consumes the budget and
    // dispatch sheds with DeadlineExceeded). The accounting identity is
    // asserted inside `open_loop_point` for every point.
    let mut ot = Table::new(&[
        "scenario", "requests", "completed", "shed", "p50 us", "p95 us", "p99 us", "peak q",
        "mean batch",
    ]);
    let mut orows: Vec<Json> = Vec::new();
    let open_n: u64 = if smoke() { 80 } else { 400 };
    let steady_serving = ServingConfig {
        exec_threads: 1,
        max_batch: 8,
        max_wait_us: 200,
        queue_cap: 4096,
        overflow: OverflowPolicy::Reject,
        default_deadline_us: 0,
    };
    let (steady_m, row) =
        open_loop_point(arch, "steady", 4, steady_serving, open_n, 150.0, 0xa11, &mut ot);
    assert_eq!(
        steady_m.rejected_total(),
        0,
        "steady point (queue_cap >= n, no deadline) must not shed"
    );
    orows.push(row);
    let overload_serving = ServingConfig {
        exec_threads: 1,
        max_batch: 4,
        max_wait_us: 100,
        queue_cap: 4,
        overflow: OverflowPolicy::Reject,
        default_deadline_us: 0,
    };
    let (over_m, row) =
        open_loop_point(arch, "overload", 2, overload_serving, open_n, 0.0, 0xb22, &mut ot);
    assert!(
        over_m.rejected_queue_full > 0,
        "burst arrivals into a depth-4 queue must shed QueueFull"
    );
    orows.push(row);
    let deadline_serving = ServingConfig {
        exec_threads: 1,
        max_batch: 1,
        max_wait_us: 0,
        queue_cap: 8192,
        overflow: OverflowPolicy::Reject,
        default_deadline_us: 2_000,
    };
    let (dl_m, row) =
        open_loop_point(arch, "deadline", 1, deadline_serving, open_n, 0.0, 0xc33, &mut ot);
    assert!(
        dl_m.rejected_deadline + dl_m.shed_deadline > 0,
        "a 2 ms deadline under burst load must shed DeadlineExceeded"
    );
    orows.push(row);

    println!("== serving throughput: cold vs warm plan cache ({n_req} requests) ==");
    print!("{}", table.render());
    println!(
        "\nplan compile (tile+compile+weights): {:.3} ms; cache lookup: {:.3} us \
         ({:.0}x cheaper)",
        compile_s * 1e3,
        lookup_s * 1e6,
        compile_s / lookup_s.max(1e-12)
    );
    println!("\n== parallel tiling (CP 1/{tiling_scale}, identical output asserted) ==");
    print!("{}", thr_table.render());
    println!(
        "cold prepare mean: {:.3} ms @ 1 thread vs {:.3} ms @ 4 threads",
        prep1 * 1e3,
        prep4 * 1e3
    );
    println!(
        "\n== batched + tile-parallel serving ({batch_requests} functional requests, \
         {batch_dataset} 1/{batch_scale}, bit-identical outputs asserted) =="
    );
    print!("{}", bt.render());
    println!("sequential warm baseline: {seq_rps:.1} req/s");
    println!(
        "\n== open-loop sustained load ({open_n} requests/point, seeded arrivals, \
         exact response accounting asserted) =="
    );
    print!("{}", ot.render());

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf_serving".to_string()));
    root.insert("sweep".to_string(), Json::Arr(rows));
    root.insert("plan_compile_s".to_string(), num(compile_s));
    root.insert("plan_lookup_s".to_string(), num(lookup_s));
    root.insert("tiling_threads".to_string(), Json::Arr(thr_rows));
    root.insert("cold_prepare_mean_s_threads1".to_string(), num(prep1));
    root.insert("cold_prepare_mean_s_threads4".to_string(), num(prep4));
    root.insert("batch_dataset".to_string(), Json::Str(batch_dataset.to_string()));
    root.insert("batch_scale".to_string(), num(batch_scale as f64));
    root.insert("batch_requests".to_string(), num(batch_requests as f64));
    root.insert("batch_sequential_req_per_s".to_string(), num(seq_rps));
    root.insert("batch_sweep".to_string(), Json::Arr(brows));
    root.insert("open_loop".to_string(), Json::Arr(orows));
    let path = "BENCH_serving.json";
    std::fs::write(path, Json::Obj(root).to_string_pretty()).expect("write BENCH_serving.json");
    println!("wrote {path}");
}
